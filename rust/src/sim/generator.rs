//! Dataset generator: reproduces the paper's Table-I census exactly.
//!
//! For each job a parameter grid (machine type × scale-out × size ×
//! context) is laid out, then deterministically subsampled to the paper's
//! unique-experiment count (Sort 126, Grep 162, SGD 180, K-Means 180,
//! PageRank 282 — 930 total). Every experiment is executed five times and
//! the median runtime recorded, mirroring §VI-B.

use crate::cloud::Catalog;
use crate::data::{Dataset, JobKind};
use crate::util::prng::Pcg;

use super::jobs::{JobInput, WorkloadModel};

/// Generator knobs.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    pub seed: u64,
    pub model: WorkloadModel,
    /// Machine types included in the shared dataset.
    pub machine_types: Vec<String>,
    /// Scale-outs included.
    pub scale_outs: Vec<u32>,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            seed: 0xC30,
            model: WorkloadModel::default(),
            // Two machine types: the Table-I census divided by more types
            // starves the per-machine-type training pools the §VI-C
            // protocol (and any real C3O deployment) depends on.
            machine_types: vec!["m5.xlarge".into(), "c5.xlarge".into()],
            scale_outs: (2..=12).collect(),
        }
    }
}

/// Job-specific grid axes: (sizes, context combinations).
fn grid_axes(job: JobKind) -> (Vec<f64>, Vec<Vec<f64>>) {
    match job {
        // Table I: Sort 10-20 GB, no parameters.
        JobKind::Sort => {
            let sizes = vec![10.0, 12.0, 14.0, 16.0, 18.0, 20.0];
            (sizes, vec![vec![]])
        }
        // Grep 10-20 GB, keyword "Computer"; hidden context = fraction of
        // lines containing the keyword.
        JobKind::Grep => {
            let sizes = vec![10.0, 12.0, 14.0, 16.0, 18.0, 20.0];
            let ratios = vec![0.001, 0.01, 0.1];
            (sizes, ratios.into_iter().map(|r| vec![r]).collect())
        }
        // SGD 10-30 GB, max iterations 1-100; second context feature is
        // the labeled-point dimensionality. Six context combinations keep
        // the per-(machine, context) pools dense enough for the paper's
        // local-training scenario (§VI-C-a).
        JobKind::Sgd => {
            let sizes = vec![10.0, 15.0, 20.0, 25.0, 30.0];
            let mut ctx = Vec::new();
            for &it in &[1.0, 25.0, 100.0] {
                for &nf in &[10.0, 100.0] {
                    ctx.push(vec![it, nf]);
                }
            }
            (sizes, ctx)
        }
        // K-Means 10-20 GB, 3-9 clusters, convergence 0.001.
        JobKind::KMeans => {
            let sizes = vec![10.0, 12.0, 14.0, 16.0, 18.0, 20.0];
            let ctx = (3..=9).map(|k| vec![k as f64, 0.001]).collect();
            (sizes, ctx)
        }
        // PageRank 130-440 MB edge lists, convergence 0.01-0.0001; hidden
        // context = unique-page ratio.
        JobKind::PageRank => {
            let sizes = vec![0.13, 0.21, 0.29, 0.36, 0.44];
            let mut ctx = Vec::new();
            for &pr in &[0.05, 0.1, 0.2] {
                for &cv in &[0.01, 0.001, 0.0001] {
                    ctx.push(vec![pr, cv]);
                }
            }
            (sizes, ctx)
        }
    }
}

/// Generate the shared dataset for one job, sized per Table I.
pub fn generate_job(
    job: JobKind,
    cfg: &GeneratorConfig,
    catalog: &Catalog,
) -> crate::Result<Dataset> {
    let (sizes, contexts) = grid_axes(job);
    // Full grid.
    let mut grid = Vec::new();
    for mt in &cfg.machine_types {
        for &s in &cfg.scale_outs {
            for &d in &sizes {
                for ctx in &contexts {
                    grid.push((mt.clone(), s, d, ctx.clone()));
                }
            }
        }
    }
    let target = job.experiment_count();
    anyhow::ensure!(
        grid.len() >= target,
        "{job}: grid {} < census {target}",
        grid.len()
    );

    // Deterministic subsample to the paper's census. Stratified by
    // (machine type, context) so every *local* training pool — one
    // machine, one context, per §VI-C — keeps enough scale-out/size
    // coverage.
    let mut rng = Pcg::new(cfg.seed, job as u64 + 1);
    let cells = cfg.machine_types.len() * contexts.len();
    let per_cell = target / cells;
    let mut chosen: Vec<(String, u32, f64, Vec<f64>)> = Vec::with_capacity(target);
    for mt in &cfg.machine_types {
        for ctx in &contexts {
            let mut pool: Vec<_> = grid
                .iter()
                .filter(|g| &g.0 == mt && &g.3 == ctx)
                .cloned()
                .collect();
            rng.shuffle(&mut pool);
            chosen.extend(pool.into_iter().take(per_cell));
        }
    }
    // Top up to the exact census from the remaining grid.
    if chosen.len() < target {
        let mut rest: Vec<_> =
            grid.iter().filter(|g| !chosen.contains(g)).cloned().collect();
        rng.shuffle(&mut rest);
        chosen.extend(rest.into_iter().take(target - chosen.len()));
    }
    chosen.truncate(target);

    let mut ds = Dataset::new(job);
    for (mt_name, s, d, ctx) in chosen {
        let mt = catalog.get(&mt_name)?;
        let input = JobInput::new(job, d, ctx);
        ds.push(cfg.model.observe(mt, s, &input, &mut rng))?;
    }
    Ok(ds)
}

/// Generate all five job datasets (the full 930-experiment corpus).
pub fn generate_all(cfg: &GeneratorConfig, catalog: &Catalog) -> crate::Result<Vec<Dataset>> {
    JobKind::ALL.iter().map(|&j| generate_job(j, cfg, catalog)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(job: JobKind) -> Dataset {
        let cfg = GeneratorConfig::default();
        generate_job(job, &cfg, &Catalog::aws_like()).unwrap()
    }

    #[test]
    fn census_matches_table1() {
        for job in JobKind::ALL {
            assert_eq!(gen(job).len(), job.experiment_count(), "{job}");
        }
    }

    #[test]
    fn total_is_930() {
        let cfg = GeneratorConfig::default();
        let all = generate_all(&cfg, &Catalog::aws_like()).unwrap();
        let total: usize = all.iter().map(|d| d.len()).sum();
        assert_eq!(total, 930);
    }

    #[test]
    fn generation_is_deterministic() {
        let a = gen(JobKind::KMeans);
        let b = gen(JobKind::KMeans);
        assert_eq!(a.records, b.records);
    }

    #[test]
    fn different_seed_different_data() {
        let cfg_a = GeneratorConfig::default();
        let cfg_b = GeneratorConfig { seed: 99, ..GeneratorConfig::default() };
        let cat = Catalog::aws_like();
        let a = generate_job(JobKind::Sort, &cfg_a, &cat).unwrap();
        let b = generate_job(JobKind::Sort, &cfg_b, &cat).unwrap();
        assert_ne!(a.records, b.records);
    }

    #[test]
    fn every_context_has_scaleout_coverage() {
        // Local training (paper §VI-C-a) needs per-context variation in
        // scale-out and size; each context must keep >= 6 records spanning
        // >= 3 distinct scale-outs.
        for job in [JobKind::Grep, JobKind::KMeans, JobKind::PageRank] {
            let ds = gen(job);
            for ctx in ds.contexts() {
                let local = ds.local_view(&ctx);
                assert!(local.len() >= 6, "{job} ctx {ctx:?}: {}", local.len());
                assert!(
                    local.scale_outs().len() >= 3,
                    "{job} ctx {ctx:?}: scale-outs {:?}",
                    local.scale_outs()
                );
            }
        }
    }

    #[test]
    fn sizes_within_table1_ranges() {
        let ds = gen(JobKind::Sgd);
        for r in &ds.records {
            assert!((10.0..=30.0).contains(&r.data_size_gb));
        }
        let ds = gen(JobKind::PageRank);
        for r in &ds.records {
            assert!((0.13..=0.44).contains(&r.data_size_gb));
        }
    }

    #[test]
    fn runtimes_positive_and_finite() {
        for job in JobKind::ALL {
            for r in &gen(job).records {
                assert!(r.runtime_s.is_finite() && r.runtime_s > 0.0);
            }
        }
    }

    #[test]
    fn covers_all_machine_types() {
        let ds = gen(JobKind::Sort);
        assert_eq!(ds.machine_types().len(), 2);
    }
}
