//! End-to-end execution simulator: provisions a cluster from the provider,
//! "runs" the job (samples a runtime from the workload model), tears the
//! cluster down, and reports the observation. This is step 5-6 of the
//! paper's Fig. 4 workflow and the substrate for `examples/e2e_c3o.rs`.

use std::sync::Mutex;

use crate::cloud::{CloudProvider, ClusterConfig};
use crate::data::RunRecord;
use crate::util::prng::Pcg;

use super::jobs::{JobInput, WorkloadModel};

/// Outcome of one simulated execution.
#[derive(Debug, Clone)]
pub struct ExecutionReport {
    pub record: RunRecord,
    pub cost_usd: f64,
    /// Wall-clock including provisioning delay.
    pub wallclock_s: f64,
    pub deadline_met: Option<bool>,
}

/// Executes jobs against the simulated provider.
pub struct Executor<'a> {
    provider: &'a CloudProvider,
    model: WorkloadModel,
    rng: Mutex<Pcg>,
}

impl<'a> Executor<'a> {
    pub fn new(provider: &'a CloudProvider, model: WorkloadModel, seed: u64) -> Self {
        Executor { provider, model, rng: Mutex::new(Pcg::new(seed, 0xE1)) }
    }

    /// Provision, run, tear down. `deadline_s` (if given) is judged against
    /// the *job* runtime, matching the paper's t_max semantics.
    pub fn run(
        &self,
        config: &ClusterConfig,
        input: &JobInput,
        deadline_s: Option<f64>,
    ) -> crate::Result<ExecutionReport> {
        let lease = self.provider.provision(config)?;
        let mt = self.provider.catalog().get(&config.machine_type)?.clone();
        let runtime_s = {
            let mut rng = self.rng.lock().unwrap();
            self.model.sample_runtime(&mt, config.scale_out, input, &mut rng)
        };
        let wallclock_s = runtime_s + lease.provisioned_after_s;
        let cost_usd = self.provider.tear_down(lease, runtime_s)?;
        Ok(ExecutionReport {
            record: RunRecord {
                machine_type: config.machine_type.clone(),
                scale_out: config.scale_out,
                data_size_gb: input.data_size_gb,
                context: input.context.clone(),
                runtime_s,
            },
            cost_usd,
            wallclock_s,
            deadline_met: deadline_s.map(|d| runtime_s <= d),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::data::JobKind;

    #[test]
    fn run_produces_consistent_report() {
        let provider = CloudProvider::new(Catalog::aws_like());
        let exec = Executor::new(&provider, WorkloadModel::default(), 7);
        let cfg = ClusterConfig { machine_type: "m5.xlarge".into(), scale_out: 4 };
        let input = JobInput::new(JobKind::Sort, 12.0, vec![]);
        let rep = exec.run(&cfg, &input, Some(1e6)).unwrap();
        assert_eq!(rep.record.scale_out, 4);
        assert!(rep.record.runtime_s > 0.0);
        assert!(rep.wallclock_s > rep.record.runtime_s);
        assert!(rep.cost_usd > 0.0);
        assert_eq!(rep.deadline_met, Some(true));
        assert_eq!(provider.active_clusters(), 0);
    }

    #[test]
    fn missed_deadline_reported() {
        let provider = CloudProvider::new(Catalog::aws_like());
        let exec = Executor::new(&provider, WorkloadModel::default(), 7);
        let cfg = ClusterConfig { machine_type: "m5.xlarge".into(), scale_out: 2 };
        let input = JobInput::new(JobKind::Sort, 20.0, vec![]);
        let rep = exec.run(&cfg, &input, Some(1.0)).unwrap();
        assert_eq!(rep.deadline_met, Some(false));
    }

    #[test]
    fn unknown_machine_type_fails_without_leak() {
        let provider = CloudProvider::new(Catalog::aws_like());
        let exec = Executor::new(&provider, WorkloadModel::default(), 7);
        let cfg = ClusterConfig { machine_type: "bogus".into(), scale_out: 2 };
        let input = JobInput::new(JobKind::Sort, 10.0, vec![]);
        assert!(exec.run(&cfg, &input, None).is_err());
        assert_eq!(provider.active_clusters(), 0);
    }
}
