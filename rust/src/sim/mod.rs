//! Distributed-dataflow runtime simulator.
//!
//! Stands in for the paper's 930 real Spark runs on Amazon EMR (the
//! `c3o-experiments` dataset is not available offline — see DESIGN.md §2).
//! `jobs.rs` holds per-job analytical cost models (scan, shuffle,
//! iteration counts, stragglers, memory-spill cliffs) over the machine-type
//! catalog; `generator.rs` reproduces the exact Table-I census; `exec.rs`
//! samples end-to-end executions for the e2e example and failure tests.

pub mod exec;
pub mod generator;
pub mod jobs;

pub use exec::Executor;
pub use generator::{generate_all, generate_job, GeneratorConfig};
pub use jobs::{JobInput, WorkloadModel};
