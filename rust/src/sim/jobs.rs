//! Analytical runtime models for the five Table-I Spark jobs.
//!
//! Each job composes the same physical phases a Spark job on a co-located
//! HDFS cluster goes through (§II of the paper):
//!
//!   startup  — driver + executor launch, grows mildly with scale-out
//!   read     — parallel HDFS scan, aggregate bandwidth ∝ nodes · io_factor
//!   compute  — data-parallel operator work, ∝ 1 / (nodes · vcpus · cpu)
//!   shuffle  — all-to-all exchange with a coordination penalty that grows
//!              with the node count (this is what makes over-provisioning
//!              costly and creates the runtime/cost sweet spot)
//!   write    — output write-back
//!
//! Iterative jobs (SGD, K-Means, PageRank) repeat compute(+shuffle) per
//! iteration over a cached working set; when the working set per node
//! exceeds usable executor memory the iteration re-reads from disk — the
//! **memory-spill cliff** the paper's §IV-B warns about ("massive runtime
//! increases over sometimes only slightly higher scale-outs").
//!
//! The absolute constants are calibrated to land in the paper's regime
//! (minutes-scale runtimes for 10-30 GB on 2-12 nodes); what the learning
//! problem needs is the *shape*: smooth in (s, d), strongly context-
//! dependent, mildly heteroscedastic, cliffed when memory-starved.

use crate::cloud::MachineType;
use crate::data::{JobKind, RunRecord};
use crate::util::prng::Pcg;

/// Per-node constants (aggregate scales with the node count).
const BASE_IO_GBPS: f64 = 0.24; // HDFS scan bandwidth per node
const BASE_NET_GBPS: f64 = 0.15; // shuffle bandwidth per node
const CORE_GBPS: f64 = 0.045; // per effective core compute throughput
const SPARK_MEM_FRACTION: f64 = 0.55; // usable executor memory share
const SPILL_PENALTY: f64 = 1.2; // slowdown factor for spilled iterations
const SPILL_RATIO_CAP: f64 = 2.5; // starvation degree cap

/// Inputs of one simulated execution.
#[derive(Debug, Clone, PartialEq)]
pub struct JobInput {
    pub job: JobKind,
    pub data_size_gb: f64,
    /// Job-specific context, in [`JobKind::context_feature_names`] order.
    pub context: Vec<f64>,
}

impl JobInput {
    pub fn new(job: JobKind, data_size_gb: f64, context: Vec<f64>) -> Self {
        JobInput { job, data_size_gb, context }
    }
}

/// The workload model: deterministic mean runtime + noisy samples.
#[derive(Debug, Clone)]
pub struct WorkloadModel {
    /// Multiplicative lognormal noise sigma (run-to-run variance).
    pub noise_sigma: f64,
}

impl Default for WorkloadModel {
    fn default() -> Self {
        WorkloadModel { noise_sigma: 0.04 }
    }
}

struct Phases {
    scan_gb: f64,
    /// One-pass CPU work, GB-equivalents.
    cpu_gb: f64,
    shuffle_gb: f64,
    write_gb: f64,
    /// Iterations of (iter_cpu_gb [+ iter_shuffle_gb]) over the cached set.
    iterations: f64,
    iter_cpu_gb: f64,
    iter_shuffle_gb: f64,
    /// Cached working set, GB (0 for single-pass jobs).
    working_set_gb: f64,
    /// One-pass in-memory buffer need (external sort); 0 if N/A. When it
    /// exceeds usable memory the cpu+shuffle phases pay a spill multiplier.
    onepass_working_gb: f64,
}

impl WorkloadModel {
    /// Noise-free expected runtime in seconds.
    pub fn mean_runtime(&self, mt: &MachineType, scale_out: u32, input: &JobInput) -> f64 {
        let ph = Self::phases(input);
        let nodes = scale_out as f64;
        let agg_io = nodes * BASE_IO_GBPS * mt.io_factor;
        let agg_net = nodes * BASE_NET_GBPS;
        let agg_cpu = nodes * mt.vcpus as f64 * mt.cpu_factor * CORE_GBPS;

        // Startup: driver + executor registration + per-wave scheduling.
        let startup = 12.0 + 1.8 * nodes.ln();
        // Shuffle coordination penalty: all-to-all has n*(n-1) flows.
        let shuffle_pen = 1.0 + 0.12 * nodes.ln();

        // External-sort-style one-pass spill: when the in-memory buffers
        // do not fit, the sort/shuffle path degrades to multi-pass merge.
        // This is deliberately NOT of Ernest's parametric form (it is a
        // thresholded d/s interaction), matching the paper's observation
        // that even context-free jobs defeat purely parametric models.
        let usable_total = mt.memory_gb * SPARK_MEM_FRACTION * nodes;
        let onepass_mult = if ph.onepass_working_gb > usable_total {
            let ratio = (ph.onepass_working_gb / usable_total).min(SPILL_RATIO_CAP);
            1.0 + SPILL_PENALTY * (ratio - 1.0)
        } else {
            1.0
        };

        let mut t = startup
            + ph.scan_gb / agg_io
            + (ph.cpu_gb / agg_cpu + ph.shuffle_gb * shuffle_pen / agg_net) * onepass_mult
            + ph.write_gb / agg_io;

        if ph.iterations > 0.0 {
            let usable = mt.memory_gb * SPARK_MEM_FRACTION * nodes;
            let spill = if ph.working_set_gb > usable {
                // Degree of starvation drives the cliff height, capped so
                // tiny clusters stay finite.
                let ratio = (ph.working_set_gb / usable).min(SPILL_RATIO_CAP);
                1.0 + SPILL_PENALTY * (ratio - 1.0)
            } else {
                1.0
            };
            // Spark's MEMORY_AND_DISK degradation is multiplicative on
            // the per-iteration time (partial spill + re-fetch), not a
            // full re-scan — the cliff is disproportionate but learnable,
            // as in the paper's EMR data.
            let per_iter = ph.iter_cpu_gb / agg_cpu
                + ph.iter_shuffle_gb * shuffle_pen / agg_net
                // Per-iteration synchronization barrier.
                + 0.35 * nodes.ln().max(1.0);
            t += ph.iterations * per_iter * spill;
        }
        t
    }

    /// One noisy sample (what a real execution would have measured).
    pub fn sample_runtime(
        &self,
        mt: &MachineType,
        scale_out: u32,
        input: &JobInput,
        rng: &mut Pcg,
    ) -> f64 {
        let mean = self.mean_runtime(mt, scale_out, input);
        // Lognormal multiplicative noise + a rare straggler tail (one slow
        // node stretches the job), mirroring the outliers the paper
        // controls for by taking the median of 5 repetitions.
        let mut t = mean * rng.lognormal_noise(self.noise_sigma);
        if rng.f64() < 0.05 {
            t *= 1.0 + 0.25 * rng.f64();
        }
        t
    }

    /// Five repetitions, median — exactly the paper's §VI-B protocol.
    pub fn median_of_five(
        &self,
        mt: &MachineType,
        scale_out: u32,
        input: &JobInput,
        rng: &mut Pcg,
    ) -> f64 {
        let mut xs: Vec<f64> =
            (0..5).map(|_| self.sample_runtime(mt, scale_out, input, rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[2]
    }

    /// Build a [`RunRecord`] from a median-of-five observation.
    pub fn observe(
        &self,
        mt: &MachineType,
        scale_out: u32,
        input: &JobInput,
        rng: &mut Pcg,
    ) -> RunRecord {
        RunRecord {
            machine_type: mt.name.clone(),
            scale_out,
            data_size_gb: input.data_size_gb,
            context: input.context.clone(),
            runtime_s: self.median_of_five(mt, scale_out, input, rng),
        }
    }

    fn phases(input: &JobInput) -> Phases {
        let d = input.data_size_gb;
        match input.job {
            // Sort: scan, O(d log d) comparison work, full shuffle, full
            // write-back.
            JobKind::Sort => {
                let logf = (d.max(2.0)).log2() / 4.0; // normalized log factor
                Phases {
                    scan_gb: d,
                    cpu_gb: 0.8 * d * logf,
                    shuffle_gb: d,
                    write_gb: d,
                    iterations: 0.0,
                    iter_cpu_gb: 0.0,
                    iter_shuffle_gb: 0.0,
                    working_set_gb: 0.0,
                    onepass_working_gb: 1.6 * d,
                }
            }
            // Grep: scan + match + output serialization; both the match
            // work and the output volume grow with the keyword-line ratio
            // (the hidden context feature single-user models miss).
            JobKind::Grep => {
                let ratio = input.context[0];
                Phases {
                    scan_gb: d,
                    cpu_gb: d * (0.25 + 3.0 * ratio),
                    shuffle_gb: 0.0,
                    write_gb: 2.0 * ratio * d,
                    iterations: 0.0,
                    iter_cpu_gb: 0.0,
                    iter_shuffle_gb: 0.0,
                    working_set_gb: 0.0,
                    onepass_working_gb: 0.0,
                }
            }
            // SGD: cache points once, then per iteration a full pass of
            // gradient work scaled by the feature count, plus a small
            // gradient aggregation shuffle. Spark's SGD converges before
            // maxIter on most datasets: effective iterations grow
            // sub-linearly in the maxIter parameter.
            JobKind::Sgd => {
                let max_iters = input.context[0];
                let nfeat = input.context[1];
                let eff_iters = 5.0 + 1.8 * max_iters.sqrt();
                let featf = (nfeat / 50.0).powf(0.35).max(0.1);
                Phases {
                    scan_gb: d,
                    cpu_gb: 0.2 * d,
                    shuffle_gb: 0.0,
                    write_gb: 0.01 * d,
                    iterations: eff_iters,
                    iter_cpu_gb: 0.22 * d * featf,
                    iter_shuffle_gb: 0.002 * d,
                    working_set_gb: 0.8 * d,
                    onepass_working_gb: 0.0,
                }
            }
            // K-Means: iterations grow with k and with tighter convergence;
            // per-iteration distance work ∝ k·d.
            JobKind::KMeans => {
                let k = input.context[0];
                let conv = input.context[1];
                let iters = 4.0 + 2.2 * (k).sqrt() * (1.0 / conv).log10();
                Phases {
                    scan_gb: d,
                    cpu_gb: 0.15 * d,
                    shuffle_gb: 0.0,
                    write_gb: 0.01 * d,
                    iterations: iters,
                    iter_cpu_gb: 0.11 * d * k / 5.0,
                    iter_shuffle_gb: 0.004 * d,
                    working_set_gb: 1.2 * d,
                    onepass_working_gb: 0.0,
                }
            }
            // PageRank: iterations ∝ log(1/conv); rank working set and the
            // per-iteration join/shuffle scale with the *unique page*
            // count (page_ratio · links), the paper's example of a hidden
            // context feature two equal-size datasets can differ in.
            JobKind::PageRank => {
                let page_ratio = input.context[0];
                let conv = input.context[1];
                let iters = 3.0 + 3.5 * (1.0 / conv).log10();
                // Graph expansion: adjacency + rank state blow up the raw
                // edge-list size considerably, scaling with the unique
                // page count.
                let expand = 18.0 + 60.0 * page_ratio;
                Phases {
                    scan_gb: d,
                    cpu_gb: 0.4 * d,
                    shuffle_gb: 0.5 * d,
                    write_gb: 0.1 * d,
                    iterations: iters,
                    // Rank updates + joins dominated by unique pages: the
                    // paper's example of equal-size datasets with "vastly
                    // different" runtimes.
                    iter_cpu_gb: 4.0 * d * (0.2 + page_ratio * 10.0),
                    iter_shuffle_gb: d * (0.4 + 3.0 * page_ratio),
                    working_set_gb: expand * d,
                    onepass_working_gb: 0.0,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::util::proptest::forall;

    fn mt(name: &str) -> MachineType {
        Catalog::aws_like().get(name).unwrap().clone()
    }

    fn sort_input(d: f64) -> JobInput {
        JobInput::new(JobKind::Sort, d, vec![])
    }

    #[test]
    fn runtimes_are_minutes_scale() {
        let m = WorkloadModel::default();
        let t = m.mean_runtime(&mt("m5.xlarge"), 4, &sort_input(15.0));
        assert!((60.0..3600.0).contains(&t), "sort 15GB on 4 nodes: {t}s");
    }

    #[test]
    fn more_nodes_speed_up_until_overhead_wins() {
        let m = WorkloadModel::default();
        let t2 = m.mean_runtime(&mt("m5.xlarge"), 2, &sort_input(20.0));
        let t8 = m.mean_runtime(&mt("m5.xlarge"), 8, &sort_input(20.0));
        assert!(t8 < t2, "t2={t2} t8={t8}");
    }

    #[test]
    fn runtime_monotone_in_data_size() {
        forall(
            "runtime increases with data size",
            100,
            |rng| {
                let d = rng.range_f64(10.0, 19.0);
                let s = rng.range(2, 13) as u32;
                (d, s)
            },
            |&(d, s)| {
                let m = WorkloadModel::default();
                let a = m.mean_runtime(&mt("m5.xlarge"), s, &sort_input(d));
                let b = m.mean_runtime(&mt("m5.xlarge"), s, &sort_input(d + 1.0));
                b > a
            },
        );
    }

    #[test]
    fn compute_type_wins_on_cpu_bound_job() {
        // SGD with many iterations is compute-bound: c5 beats m5.
        let m = WorkloadModel::default();
        let input = JobInput::new(JobKind::Sgd, 10.0, vec![100.0, 100.0]);
        let t_m5 = m.mean_runtime(&mt("m5.xlarge"), 6, &input);
        let t_c5 = m.mean_runtime(&mt("c5.xlarge"), 6, &input);
        assert!(t_c5 < t_m5, "c5={t_c5} m5={t_m5}");
    }

    #[test]
    fn memory_type_wins_on_spilling_job() {
        // PageRank's working set spills on 8 GB c5 nodes but fits on r5.
        let m = WorkloadModel::default();
        let input = JobInput::new(JobKind::PageRank, 0.4, vec![0.2, 0.0001]);
        let t_c5 = m.mean_runtime(&mt("c5.xlarge"), 2, &input);
        let t_r5 = m.mean_runtime(&mt("r5.xlarge"), 2, &input);
        assert!(t_r5 < t_c5, "r5={t_r5} c5={t_c5}");
    }

    #[test]
    fn spill_cliff_exists_for_kmeans() {
        // Paper §IV-B: insufficient scale-out -> dataset does not fit in
        // cluster memory -> massive runtime increase vs slightly more
        // nodes. c5.xlarge has 8 GB => usable 4.4 GB/node; 20 GB * 1.2
        // working set needs ~6 nodes.
        let m = WorkloadModel::default();
        let input = JobInput::new(JobKind::KMeans, 20.0, vec![9.0, 0.001]);
        let t3 = m.mean_runtime(&mt("c5.xlarge"), 3, &input);
        let t6 = m.mean_runtime(&mt("c5.xlarge"), 6, &input);
        // The cliff: 3->6 nodes must be disproportionally (>2.5x) faster.
        assert!(t3 / t6 > 2.5, "t3={t3} t6={t6}");
    }

    #[test]
    fn context_changes_runtime_at_equal_size() {
        // The paper's PageRank example: same GB, different unique pages =>
        // vastly different runtimes.
        let m = WorkloadModel::default();
        let a = JobInput::new(JobKind::PageRank, 0.3, vec![0.05, 0.001]);
        let b = JobInput::new(JobKind::PageRank, 0.3, vec![0.2, 0.001]);
        let ta = m.mean_runtime(&mt("r5.xlarge"), 6, &a);
        let tb = m.mean_runtime(&mt("r5.xlarge"), 6, &b);
        assert!(tb / ta > 1.3, "ta={ta} tb={tb}");
    }

    #[test]
    fn grep_ratio_is_a_real_context_feature() {
        // The keyword-line ratio must move the runtime noticeably (it is
        // the hidden context single-user models miss, §VI-C-a) while
        // staying far smaller than e.g. SGD's iteration effect.
        let m = WorkloadModel::default();
        let lo = JobInput::new(JobKind::Grep, 15.0, vec![0.001]);
        let hi = JobInput::new(JobKind::Grep, 15.0, vec![0.1]);
        let tl = m.mean_runtime(&mt("m5.xlarge"), 4, &lo);
        let th = m.mean_runtime(&mt("m5.xlarge"), 4, &hi);
        assert!(th / tl > 1.1, "tl={tl} th={th}");
        assert!(th / tl < 2.0, "tl={tl} th={th}");
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let m = WorkloadModel::default();
        let input = sort_input(12.0);
        let a = m.sample_runtime(&mt("m5.xlarge"), 4, &input, &mut Pcg::seed(9));
        let b = m.sample_runtime(&mt("m5.xlarge"), 4, &input, &mut Pcg::seed(9));
        assert_eq!(a, b);
    }

    #[test]
    fn median_of_five_controls_stragglers() {
        let m = WorkloadModel { noise_sigma: 0.04 };
        let input = sort_input(12.0);
        let mean = m.mean_runtime(&mt("m5.xlarge"), 4, &input);
        let mut rng = Pcg::seed(1);
        for _ in 0..50 {
            let med = m.median_of_five(&mt("m5.xlarge"), 4, &input, &mut rng);
            // Median of five stays within ~15% of the mean despite the
            // straggler tail.
            assert!((med / mean - 1.0).abs() < 0.15, "med={med} mean={mean}");
        }
    }

    #[test]
    fn sgd_iterations_dominate() {
        // Effective iterations grow sub-linearly (Spark converges before
        // maxIter), but the parameter still dominates the runtime.
        let m = WorkloadModel::default();
        let few = JobInput::new(JobKind::Sgd, 10.0, vec![1.0, 50.0]);
        let many = JobInput::new(JobKind::Sgd, 10.0, vec![100.0, 50.0]);
        let tf = m.mean_runtime(&mt("m5.xlarge"), 6, &few);
        let tm = m.mean_runtime(&mt("m5.xlarge"), 6, &many);
        assert!(tm / tf > 2.0, "tf={tf} tm={tm}");
    }
}
