//! Evaluation harnesses reproducing the paper's §VI experiments.
//!
//! * [`table2`] — runtime prediction accuracy, local vs global training
//!   data (paper Table II).
//! * [`fig5`] — accuracy vs training-data availability (paper Fig. 5).
//!
//! Both are driven by the `benches/` binaries and the `c3o eval` CLI; the
//! split protocol follows §VI-C: 300 uniformly drawn train-test splits per
//! cell, mean of the per-split MAPEs.

pub mod fig5;
pub mod table2;

pub use fig5::{run_fig5, Fig5Config, Fig5Result};
pub use table2::{run_table2, Scenario, Table2Cell, Table2Config, Table2Result};

use std::sync::Arc;

use crate::models::{Bom, C3oPredictor, Ernest, Gbm, GbmParams, Ogb, RuntimeModel};
use crate::runtime::FitBackend;

/// Model names in the paper's Table II row order.
pub const MODEL_ORDER: [&str; 5] = ["Ernest", "GBM", "BOM", "OGB", "C3O"];

/// Instantiate the evaluated models (Ernest baseline + the three
/// constituents + the C3O selector), all unfitted.
pub fn make_models(backend: &Arc<dyn FitBackend>) -> Vec<Box<dyn RuntimeModel>> {
    vec![
        Box::new(Ernest::new(backend.clone())),
        Box::new(Gbm::new(GbmParams::default())),
        Box::new(Bom::new(backend.clone())),
        Box::new(Ogb::with_defaults()),
        Box::new(C3oPredictor::new(backend.clone())),
    ]
}

/// The machine type the evaluation fixes per §VI-C ("the models only
/// learned from training data that was generated on the target machine
/// type").
pub const TARGET_MACHINE: &str = "m5.xlarge";
