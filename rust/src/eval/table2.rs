//! Table II: prediction accuracy with local-only vs globally shared
//! training data.
//!
//! Protocol (§VI-C-a): for every (job, model, scenario) cell, 300
//! train-test splits drawn uniformly; *local* splits restrict training
//! data to a single execution context (chosen uniformly among the job's
//! contexts), *global* splits draw from all contexts. Reported number is
//! the mean of per-split MAPEs. Sort has no context features, so its
//! local and global columns coincide (one shared column in the paper).

use std::sync::Arc;

use crate::data::{Dataset, JobKind};
use crate::models::TrainData;
use crate::runtime::FitBackend;
use crate::util::par::par_map;
use crate::util::prng::Pcg;
use crate::util::stats;

use super::{make_models, MODEL_ORDER};

/// Which training-data pool a split draws from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Single-user: one execution context only.
    Local,
    /// Collaborative: all contexts (§VI-C-a "global").
    Global,
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Table2Config {
    /// Train-test splits per cell (paper: 300).
    pub splits: usize,
    /// Training fraction of the pool per split.
    pub train_frac: f64,
    pub seed: u64,
    /// Worker threads (0 = available parallelism).
    pub threads: usize,
}

impl Default for Table2Config {
    fn default() -> Self {
        Table2Config { splits: 300, train_frac: 0.8, seed: 0x7AB1E2, threads: 0 }
    }
}

/// One cell of Table II.
#[derive(Debug, Clone)]
pub struct Table2Cell {
    pub job: JobKind,
    pub model: String,
    pub scenario: Scenario,
    /// Mean over splits of per-split MAPE (%).
    pub mape: f64,
    /// Std over splits (not in the paper's table; useful for CI).
    pub mape_std: f64,
    pub splits: usize,
}

/// Full harness output.
#[derive(Debug, Clone)]
pub struct Table2Result {
    pub cells: Vec<Table2Cell>,
}

impl Table2Result {
    pub fn get(&self, job: JobKind, model: &str, scenario: Scenario) -> Option<&Table2Cell> {
        self.cells
            .iter()
            .find(|c| c.job == job && c.model == model && c.scenario == scenario)
    }
}

/// Evaluate one job's dataset (already restricted to the target machine
/// type) for one scenario; returns per-model mean MAPE over splits.
pub fn eval_job_scenario(
    ds: &Dataset,
    scenario: Scenario,
    cfg: &Table2Config,
    backend: &Arc<dyn FitBackend>,
) -> crate::Result<Vec<Table2Cell>> {
    anyhow::ensure!(!ds.is_empty(), "empty dataset for {}", ds.job);
    // Local scenario: only contexts dense enough to train on (a real
    // single user would have at least a handful of past runs).
    let contexts: Vec<Vec<f64>> = ds
        .contexts()
        .into_iter()
        .filter(|c| ds.local_view(c).len() >= 6)
        .collect();
    anyhow::ensure!(
        scenario == Scenario::Global || !contexts.is_empty(),
        "{}: no context has >= 6 records for the local scenario",
        ds.job
    );

    // Per-split evaluation: returns MAPE per model (MODEL_ORDER order).
    let split_ids: Vec<usize> = (0..cfg.splits).collect();
    let per_split: Vec<crate::Result<Vec<f64>>> = par_map(&split_ids, cfg.threads, |_, &sid| {
        let mut rng = Pcg::new(cfg.seed ^ (ds.job as u64) << 32, sid as u64);
        // Choose the pool.
        let pool: Dataset = match scenario {
            Scenario::Global => ds.clone(),
            Scenario::Local => {
                let ctx = &contexts[rng.below(contexts.len().max(1))];
                ds.local_view(ctx)
            }
        };
        let n = pool.len();
        anyhow::ensure!(n >= 6, "pool too small ({n}) for {}", ds.job);
        let n_train = ((n as f64 * cfg.train_frac).round() as usize).clamp(4, n - 1);
        let (train_idx, test_idx) = crate::cv::train_test_split(n, n_train, &mut rng);

        let all = TrainData::from_dataset(&pool)?;
        let train = all.subset(&train_idx);
        let test = all.subset(&test_idx);

        let mut out = Vec::with_capacity(MODEL_ORDER.len());
        for mut model in make_models(backend) {
            let mape = match model.fit(&train) {
                Ok(()) => {
                    let preds = model.predict(&test.x)?;
                    stats::mape(&preds, &test.y)
                }
                // A model that cannot fit this split (e.g. BOM-degenerate
                // local pools) is excluded from that split's average.
                Err(e) => {
                    crate::obs::log::debug(
                        "eval.table2",
                        "model fit failed on split",
                        &[
                            ("split", sid.to_string()),
                            ("model", model.name().to_string()),
                            ("error", format!("{e:#}")),
                        ],
                    );
                    f64::NAN
                }
            };
            out.push(mape);
        }
        Ok(out)
    });

    // Aggregate.
    let mut cells = Vec::new();
    for (mi, name) in MODEL_ORDER.iter().enumerate() {
        let vals: Vec<f64> = per_split
            .iter()
            .filter_map(|r| r.as_ref().ok())
            .map(|v| v[mi])
            .filter(|v| v.is_finite())
            .collect();
        if vals.len() < cfg.splits / 2 {
            let first_err = per_split
                .iter()
                .find_map(|r| r.as_ref().err())
                .map(|e| format!("{e:#}"))
                .unwrap_or_else(|| "NaN scores".into());
            anyhow::bail!(
                "{}/{}: too many failed splits for {name} (first error: {first_err})",
                vals.len(),
                cfg.splits
            );
        }
        cells.push(Table2Cell {
            job: ds.job,
            model: name.to_string(),
            scenario,
            mape: stats::mean(&vals),
            mape_std: stats::std_dev(&vals),
            splits: vals.len(),
        });
    }
    Ok(cells)
}

/// Run the full Table II over the given per-job datasets (already
/// machine-filtered).
pub fn run_table2(
    datasets: &[Dataset],
    cfg: &Table2Config,
    backend: &Arc<dyn FitBackend>,
) -> crate::Result<Table2Result> {
    let mut cells = Vec::new();
    for ds in datasets {
        let scenarios: &[Scenario] = if ds.job.context_features() == 0 {
            // Sort: local == global (single column in the paper).
            &[Scenario::Global]
        } else {
            &[Scenario::Local, Scenario::Global]
        };
        for &sc in scenarios {
            cells.extend(eval_job_scenario(ds, sc, cfg, backend)?);
        }
    }
    Ok(Table2Result { cells })
}

/// Render the result in the paper's layout.
pub fn render(result: &Table2Result) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    writeln!(
        s,
        "Table II: Runtime Prediction Accuracy (MAPE %), local vs global training data"
    )
    .unwrap();
    for job in JobKind::ALL {
        let any = result.cells.iter().any(|c| c.job == job);
        if !any {
            continue;
        }
        writeln!(s, "\n  {job}").unwrap();
        writeln!(s, "    {:<8} {:>8} {:>8}", "model", "local", "global").unwrap();
        for model in MODEL_ORDER {
            let l = result.get(job, model, Scenario::Local);
            let g = result.get(job, model, Scenario::Global);
            let fmt = |c: Option<&Table2Cell>| match c {
                Some(c) => format!("{:.2}%", c.mape),
                None => "—".to_string(),
            };
            writeln!(s, "    {:<8} {:>8} {:>8}", model, fmt(l), fmt(g)).unwrap();
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::runtime::NativeBackend;
    use crate::sim::{generate_job, GeneratorConfig};

    fn quick_cfg() -> Table2Config {
        Table2Config { splits: 12, threads: 0, ..Default::default() }
    }

    fn machine_ds(job: JobKind) -> Dataset {
        let ds =
            generate_job(job, &GeneratorConfig::default(), &Catalog::aws_like()).unwrap();
        ds.for_machine(super::super::TARGET_MACHINE)
    }

    #[test]
    fn produces_all_models_for_grep() {
        let ds = machine_ds(JobKind::Grep);
        let backend: Arc<dyn crate::runtime::FitBackend> = Arc::new(NativeBackend::new());
        let cells = eval_job_scenario(&ds, Scenario::Global, &quick_cfg(), &backend).unwrap();
        assert_eq!(cells.len(), 5);
        for c in &cells {
            assert!(c.mape.is_finite() && c.mape >= 0.0, "{c:?}");
        }
    }

    #[test]
    fn sort_gets_single_scenario() {
        let ds = machine_ds(JobKind::Sort);
        let backend: Arc<dyn crate::runtime::FitBackend> = Arc::new(NativeBackend::new());
        let result = run_table2(std::slice::from_ref(&ds), &quick_cfg(), &backend).unwrap();
        assert!(result.get(JobKind::Sort, "GBM", Scenario::Global).is_some());
        assert!(result.get(JobKind::Sort, "GBM", Scenario::Local).is_none());
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = machine_ds(JobKind::Sort);
        let backend: Arc<dyn crate::runtime::FitBackend> = Arc::new(NativeBackend::new());
        let a = eval_job_scenario(&ds, Scenario::Global, &quick_cfg(), &backend).unwrap();
        let b = eval_job_scenario(&ds, Scenario::Global, &quick_cfg(), &backend).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.mape, y.mape);
        }
    }

    #[test]
    fn render_contains_headline_models() {
        let ds = machine_ds(JobKind::Sort);
        let backend: Arc<dyn crate::runtime::FitBackend> = Arc::new(NativeBackend::new());
        let result = run_table2(std::slice::from_ref(&ds), &quick_cfg(), &backend).unwrap();
        let text = render(&result);
        for m in MODEL_ORDER {
            assert!(text.contains(m), "{text}");
        }
    }
}
