//! Fig. 5: prediction accuracy vs training-data availability.
//!
//! Protocol (§VI-C-b): train-test splits with 3, 6, …, 30 training points
//! drawn from the *global* pool (collaborative conditions: high feature
//! dimensionality, little data), the rest forming the test set; 300 splits
//! per point; mean of per-split MAPEs.

use std::sync::Arc;

use crate::data::Dataset;
use crate::models::TrainData;
use crate::runtime::FitBackend;
use crate::util::par::par_map;
use crate::util::prng::Pcg;
use crate::util::stats;

use super::{make_models, MODEL_ORDER};

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Fig5Config {
    /// Training-set sizes (paper: 3, 6, ..., 30).
    pub train_sizes: Vec<usize>,
    /// Splits per (job, size) point (paper: 300).
    pub splits: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for Fig5Config {
    fn default() -> Self {
        Fig5Config {
            train_sizes: (1..=10).map(|k| 3 * k).collect(),
            splits: 300,
            seed: 0xF165,
            threads: 0,
        }
    }
}

/// One curve point: (model, train size) → mean MAPE.
#[derive(Debug, Clone)]
pub struct Fig5Point {
    pub model: String,
    pub train_size: usize,
    pub mape: f64,
    pub splits: usize,
}

/// One job's family of curves.
#[derive(Debug, Clone)]
pub struct Fig5Result {
    pub job: crate::data::JobKind,
    pub points: Vec<Fig5Point>,
}

impl Fig5Result {
    pub fn series(&self, model: &str) -> Vec<(usize, f64)> {
        self.points
            .iter()
            .filter(|p| p.model == model)
            .map(|p| (p.train_size, p.mape))
            .collect()
    }
}

/// Run Fig. 5 for one job dataset (already machine-filtered).
pub fn run_fig5(
    ds: &Dataset,
    cfg: &Fig5Config,
    backend: &Arc<dyn FitBackend>,
) -> crate::Result<Fig5Result> {
    let all = TrainData::from_dataset(ds)?;
    let n = all.len();
    let mut points = Vec::new();

    for &size in &cfg.train_sizes {
        anyhow::ensure!(size < n, "train size {size} >= dataset {n}");
        let split_ids: Vec<usize> = (0..cfg.splits).collect();
        let per_split: Vec<Vec<f64>> = par_map(&split_ids, cfg.threads, |_, &sid| {
            let mut rng =
                Pcg::new(cfg.seed ^ ((ds.job as u64) << 24) ^ ((size as u64) << 40), sid as u64);
            let (train_idx, test_idx) = crate::cv::train_test_split(n, size, &mut rng);
            let train = all.subset(&train_idx);
            let test = all.subset(&test_idx);
            let mut out = Vec::with_capacity(MODEL_ORDER.len());
            for mut model in make_models(backend) {
                let mape = match model.fit(&train) {
                    Ok(()) => match model.predict(&test.x) {
                        Ok(preds) => stats::mape(&preds, &test.y),
                        Err(_) => f64::NAN,
                    },
                    Err(_) => f64::NAN,
                };
                out.push(mape);
            }
            out
        });

        for (mi, name) in MODEL_ORDER.iter().enumerate() {
            let vals: Vec<f64> = per_split
                .iter()
                .map(|v| v[mi])
                .filter(|v| v.is_finite())
                .collect();
            points.push(Fig5Point {
                model: name.to_string(),
                train_size: size,
                // All splits failing (e.g. Ernest needs >=2) would be a
                // harness bug; guarded by the filter + mean of the rest.
                mape: stats::mean(&vals),
                splits: vals.len(),
            });
        }
    }
    Ok(Fig5Result { job: ds.job, points })
}

/// Render one job's curves as an aligned text table (plus CSV lines for
/// plotting).
pub fn render(result: &Fig5Result) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    writeln!(s, "Fig. 5 — {}: MAPE (%) vs training-set size", result.job).unwrap();
    write!(s, "    {:<6}", "n").unwrap();
    for m in MODEL_ORDER {
        write!(s, "{:>9}", m).unwrap();
    }
    writeln!(s).unwrap();
    let sizes: Vec<usize> = {
        let mut v: Vec<usize> = result.points.iter().map(|p| p.train_size).collect();
        v.sort_unstable();
        v.dedup();
        v
    };
    for size in sizes {
        write!(s, "    {:<6}", size).unwrap();
        for m in MODEL_ORDER {
            let p = result
                .points
                .iter()
                .find(|p| p.model == m && p.train_size == size)
                .unwrap();
            write!(s, "{:>8.2}%", p.mape).unwrap();
        }
        writeln!(s).unwrap();
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Catalog;
    use crate::data::JobKind;
    use crate::runtime::NativeBackend;
    use crate::sim::{generate_job, GeneratorConfig};

    fn quick() -> (Dataset, Fig5Config, Arc<dyn FitBackend>) {
        let ds = generate_job(JobKind::Grep, &GeneratorConfig::default(), &Catalog::aws_like())
            .unwrap()
            .for_machine(super::super::TARGET_MACHINE);
        let cfg = Fig5Config {
            train_sizes: vec![3, 9, 15],
            splits: 10,
            ..Default::default()
        };
        (ds, cfg, Arc::new(NativeBackend::new()))
    }

    #[test]
    fn produces_every_model_series() {
        let (ds, cfg, backend) = quick();
        let r = run_fig5(&ds, &cfg, &backend).unwrap();
        for m in MODEL_ORDER {
            let series = r.series(m);
            assert_eq!(series.len(), 3, "{m}");
            for (_, mape) in series {
                assert!(mape.is_finite() && mape >= 0.0);
            }
        }
    }

    #[test]
    fn accuracy_improves_with_more_data_for_gbm() {
        let (ds, mut cfg, backend) = quick();
        cfg.train_sizes = vec![3, 30];
        cfg.splits = 30;
        let r = run_fig5(&ds, &cfg, &backend).unwrap();
        let s = r.series("GBM");
        assert!(s[1].1 < s[0].1, "GBM: {s:?}");
    }

    #[test]
    fn render_mentions_all_sizes() {
        let (ds, cfg, backend) = quick();
        let r = run_fig5(&ds, &cfg, &backend).unwrap();
        let text = render(&r);
        for size in ["3", "9", "15"] {
            assert!(text.contains(size));
        }
    }

    #[test]
    fn oversized_train_request_rejected() {
        let (ds, mut cfg, backend) = quick();
        cfg.train_sizes = vec![10_000];
        assert!(run_fig5(&ds, &cfg, &backend).is_err());
    }
}
