//! Dependency-free telemetry for the hub (DESIGN.md §13).
//!
//! Three pieces:
//!
//! - [`hist`] — lock-free log-linear latency histograms, recorded per
//!   request stage via per-thread shards and aggregated on read;
//! - [`trace`] — per-request spans carrying the correlation id through
//!   the reactor, worker pool and write path, retained in a ring and
//!   promoted to a slow-request log past `--slow-ms`;
//! - [`log`] — the structured leveled logger that replaced the ad-hoc
//!   `eprintln!` sites (lint rule L6 forbids new ones).
//!
//! The registry ([`metrics`]) is process-wide, like a default
//! Prometheus registry: deep layers (`storage/wal.rs`,
//! `cv/parallel.rs`) record stages without constructor plumbing, and
//! the `metrics` op snapshots it. The trade-off is that two hubs in one
//! process (as in tests) share histograms; the e2e assertions therefore
//! check nonzero counts and internal consistency, never exact totals.

pub mod hist;
pub mod log;
pub mod trace;

use std::sync::atomic::AtomicU64;
use std::sync::OnceLock;

pub use hist::{Histogram, Snapshot};
pub use trace::{now_us, Span, TraceRing};

/// Completed traces retained by the global ring.
const TRACE_RING_CAP: usize = 128;

/// A request-path stage with its own latency histogram. `name()` is the
/// wire/metric identifier (`c3o_stage_<name>_us` in Prometheus text).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Frame extraction in the reactor.
    Decode,
    /// Job queue residency before a worker picks it up.
    QueueWait,
    /// Full service dispatch in the worker.
    Service,
    /// Model fit inside the service (cold cache path).
    Fit,
    /// Candidate scoring in the fit engine (`cv/parallel.rs`).
    CvScore,
    /// Row prediction against a fitted model.
    Predict,
    /// WAL record append (write syscall path).
    WalAppend,
    /// WAL fsync.
    WalFsync,
    /// Reply residency in the outbox (worker -> reactor handoff).
    Dispatch,
    /// Reply bytes sitting in the write buffer until flushed.
    ReplyWrite,
    /// End-to-end: frame decode start to reply flush.
    Total,
}

impl Stage {
    pub const ALL: [Stage; 11] = [
        Stage::Decode,
        Stage::QueueWait,
        Stage::Service,
        Stage::Fit,
        Stage::CvScore,
        Stage::Predict,
        Stage::WalAppend,
        Stage::WalFsync,
        Stage::Dispatch,
        Stage::ReplyWrite,
        Stage::Total,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Decode => "decode",
            Stage::QueueWait => "queue_wait",
            Stage::Service => "service",
            Stage::Fit => "fit",
            Stage::CvScore => "cv_score",
            Stage::Predict => "predict",
            Stage::WalAppend => "wal_append",
            Stage::WalFsync => "wal_fsync",
            Stage::Dispatch => "dispatch",
            Stage::ReplyWrite => "reply_write",
            Stage::Total => "request_total",
        }
    }
}

/// The process-wide telemetry registry: one histogram per [`Stage`],
/// the completed-trace ring, and gauges owned by the serving path.
pub struct Metrics {
    stages: [Histogram; Stage::ALL.len()],
    pub traces: TraceRing,
    /// Workers currently inside a service dispatch.
    pub busy_workers: AtomicU64,
    /// Worker pool size of the most recently started hub.
    pub workers_total: AtomicU64,
}

impl Metrics {
    fn new() -> Self {
        Metrics {
            stages: std::array::from_fn(|_| Histogram::new()),
            traces: TraceRing::new(TRACE_RING_CAP),
            busy_workers: AtomicU64::new(0),
            workers_total: AtomicU64::new(0),
        }
    }

    /// The histogram for one stage.
    pub fn stage(&self, stage: Stage) -> &Histogram {
        // `position` over Stage::ALL is always < stages.len() because
        // both arrays share the same length by construction.
        let idx = Stage::ALL.iter().position(|s| *s == stage).unwrap_or(0);
        &self.stages[idx]
    }

    /// Record one value (microseconds) into a stage histogram.
    pub fn record(&self, stage: Stage, value_us: u64) {
        self.stage(stage).record(value_us);
    }

    /// Record elapsed time since a [`now_us`] reading into a stage.
    pub fn record_since(&self, stage: Stage, start_us: u64) {
        self.stage(stage).record_since(start_us);
    }
}

/// The global registry (created on first use).
pub fn metrics() -> &'static Metrics {
    static METRICS: OnceLock<Metrics> = OnceLock::new();
    METRICS.get_or_init(Metrics::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_are_unique() {
        let mut names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), Stage::ALL.len());
    }

    #[test]
    fn registry_records_into_the_right_stage() {
        let m = metrics();
        let before = m.stage(Stage::CvScore).snapshot().count;
        m.record(Stage::CvScore, 250);
        let after = m.stage(Stage::CvScore).snapshot();
        assert_eq!(after.count, before + 1);
    }
}
