//! Request tracing: per-request spans with a stage breakdown
//! (DESIGN.md §13).
//!
//! A span is opened by the reactor when it decodes a frame and travels
//! with the job through the worker pool and back out through the write
//! buffer; the reactor completes it when the last byte of the reply has
//! been flushed to the socket. Stages are disjoint sub-intervals of the
//! request's wall-clock lifetime, so
//! `decode + queue + service + dispatch + reply <= total` holds by
//! construction.
//!
//! Completed spans land in a fixed-capacity ring ([`TraceRing`]) for
//! inspection, and requests slower than the configured `--slow-ms`
//! threshold are additionally promoted to a structured warn-level
//! slow-request log line.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::log;

/// Microseconds since an arbitrary process-wide monotonic epoch (the
/// first call). All span timestamps use this clock.
pub fn now_us() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// One completed request trace: correlation id, op, and the per-stage
/// breakdown in microseconds.
#[derive(Clone, Debug, Default)]
pub struct Span {
    /// Correlation id from the request frame (0 if the frame carried
    /// none or failed to parse).
    pub id: u64,
    /// Op name ("predict", "submit", ...; empty if undecodable).
    pub op: String,
    /// [`now_us`] timestamp when the reactor pulled the frame out of
    /// the read buffer.
    pub recv_us: u64,
    /// Frame extraction time in the reactor.
    pub decode_us: u64,
    /// Time spent queued before a worker picked the job up.
    pub queue_us: u64,
    /// Service dispatch time in the worker (includes fit/predict/WAL).
    pub service_us: u64,
    /// Outbox residency: reply handoff back to the reactor.
    pub dispatch_us: u64,
    /// Time from entering the connection's write buffer to the last
    /// byte being flushed to the socket.
    pub reply_us: u64,
    /// End-to-end: frame decode start to reply flush.
    pub total_us: u64,
    /// Whether the response carried `ok: true`.
    pub ok: bool,
}

/// Fixed-capacity ring of recently completed spans plus slow-request
/// accounting. Shared by reference from the global metrics registry.
pub struct TraceRing {
    cap: usize,
    recent: Mutex<VecDeque<Span>>,
    completed: AtomicU64,
    slow: AtomicU64,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        TraceRing {
            cap: cap.max(1),
            recent: Mutex::new(VecDeque::new()),
            completed: AtomicU64::new(0),
            slow: AtomicU64::new(0),
        }
    }

    /// Record a completed span. If `slow_ms` is nonzero and the span's
    /// end-to-end time reaches it, the span is also promoted to a
    /// structured slow-request log line.
    pub fn complete(&self, span: Span, slow_ms: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if slow_ms > 0 && span.total_us >= slow_ms.saturating_mul(1000) {
            self.slow.fetch_add(1, Ordering::Relaxed);
            log::warn(
                "hub.trace",
                "slow request",
                &[
                    ("id", span.id.to_string()),
                    ("op", span.op.clone()),
                    ("total_us", span.total_us.to_string()),
                    ("queue_us", span.queue_us.to_string()),
                    ("service_us", span.service_us.to_string()),
                    ("reply_us", span.reply_us.to_string()),
                ],
            );
        }
        let mut ring = self.recent.lock().unwrap_or_else(|e| e.into_inner());
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(span);
    }

    /// The retained spans, oldest first.
    pub fn recent(&self) -> Vec<Span> {
        self.recent
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .cloned()
            .collect()
    }

    /// Total spans completed over the process lifetime.
    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// Spans promoted to the slow-request log.
    pub fn slow(&self) -> u64 {
        self.slow.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64, total_us: u64) -> Span {
        Span {
            id,
            op: "predict".into(),
            total_us,
            ok: true,
            ..Span::default()
        }
    }

    #[test]
    fn ring_retains_last_n_in_completion_order() {
        let ring = TraceRing::new(3);
        for id in 1..=5u64 {
            ring.complete(span(id, 10), 0);
        }
        let ids: Vec<u64> = ring.recent().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![3, 4, 5]);
        assert_eq!(ring.completed(), 5);
        assert_eq!(ring.slow(), 0);
    }

    #[test]
    fn slow_threshold_promotes_to_log() {
        let cap = log::capture();
        let ring = TraceRing::new(8);
        ring.complete(span(1, 900), 1); // 0.9 ms < 1 ms
        ring.complete(span(2, 2_500), 1); // 2.5 ms >= 1 ms
        assert_eq!(ring.slow(), 1);
        let slow: Vec<_> = cap
            .take()
            .into_iter()
            .filter(|r| r.target == "hub.trace")
            .collect();
        assert_eq!(slow.len(), 1);
        assert!(slow[0].fields.iter().any(|(k, v)| k == "id" && v == "2"));
    }

    #[test]
    fn zero_threshold_disables_slow_log() {
        let ring = TraceRing::new(2);
        ring.complete(span(1, u64::MAX), 0);
        assert_eq!(ring.slow(), 0);
    }
}
