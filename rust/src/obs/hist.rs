//! Lock-free log-linear latency histograms (DESIGN.md §13).
//!
//! Values are microseconds. The bucket scheme is HDR-style log-linear:
//! values below 16 get one exact bucket each; every higher power-of-two
//! octave is split into 16 linear sub-buckets, so the relative error of
//! any bucket is at most 1/16 (6.25%). With 64-bit values that is
//! `16 * 61 = 976` buckets total — small enough to keep one atomic
//! counter array per shard and merge shards on read.
//!
//! Recording is wait-free: pick a shard by thread, `fetch_add` one
//! bucket, `fetch_add` the sum, `fetch_max` the max. Reads aggregate
//! all shards into an owned [`Snapshot`] whose `count` is derived from
//! the bucket counters themselves, so a snapshot is always internally
//! consistent even while writers race.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Linear sub-buckets per octave as a power of two.
const SUB_BITS: u32 = 4;
/// Linear sub-buckets per octave (16).
const SUBS: usize = 1 << SUB_BITS;
/// Total bucket count: one exact bucket per value below `SUBS`, plus
/// `SUBS` sub-buckets for each octave with msb in `SUB_BITS..=63`.
pub const BUCKETS: usize = SUBS * (64 - SUB_BITS as usize + 1);

/// Per-histogram shard count. Shards only reduce write contention;
/// any thread may record into any shard and reads merge them all.
const SHARDS: usize = 8;

/// Map a value to its bucket index (0..`BUCKETS`).
pub fn bucket_index(v: u64) -> usize {
    if v < SUBS as u64 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros();
        let octave = (msb - SUB_BITS + 1) as usize;
        octave * SUBS + ((v >> (msb - SUB_BITS)) as usize & (SUBS - 1))
    }
}

/// Smallest value that lands in bucket `idx`.
pub fn bucket_lower(idx: usize) -> u64 {
    if idx < SUBS {
        idx as u64
    } else if idx >= BUCKETS {
        u64::MAX
    } else {
        let msb = (idx / SUBS) as u32 + SUB_BITS - 1;
        let sub = (idx % SUBS) as u64;
        (1u64 << msb) + (sub << (msb - SUB_BITS))
    }
}

/// Largest value that lands in bucket `idx`.
pub fn bucket_upper(idx: usize) -> u64 {
    if idx + 1 >= BUCKETS {
        u64::MAX
    } else {
        bucket_lower(idx + 1) - 1
    }
}

struct Shard {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
}

impl Shard {
    fn new() -> Self {
        Shard {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
        }
    }
}

/// A sharded, mergeable latency histogram. All methods take `&self`;
/// the struct is safe to share behind an `Arc` or a `static`.
pub struct Histogram {
    shards: Vec<Shard>,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            shards: (0..SHARDS).map(|_| Shard::new()).collect(),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value (microseconds). Wait-free.
    pub fn record(&self, value_us: u64) {
        let idx = bucket_index(value_us);
        if let Some(shard) = self.shards.get(shard_of(self.shards.len())) {
            if let Some(bucket) = shard.buckets.get(idx) {
                bucket.fetch_add(1, Ordering::Relaxed);
                shard.sum.fetch_add(value_us, Ordering::Relaxed);
                self.max.fetch_max(value_us, Ordering::Relaxed);
            }
        }
    }

    /// Record the elapsed time since `start_us` (a [`super::now_us`]
    /// reading), saturating at zero if the clock reads backwards.
    pub fn record_since(&self, start_us: u64) {
        self.record(super::now_us().saturating_sub(start_us));
    }

    /// Aggregate every shard into an owned, internally consistent view.
    pub fn snapshot(&self) -> Snapshot {
        let mut buckets = vec![0u64; BUCKETS];
        let mut sum = 0u64;
        for shard in &self.shards {
            for (acc, bucket) in buckets.iter_mut().zip(&shard.buckets) {
                *acc += bucket.load(Ordering::Relaxed);
            }
            sum = sum.saturating_add(shard.sum.load(Ordering::Relaxed));
        }
        let count = buckets.iter().sum();
        Snapshot {
            buckets,
            count,
            sum,
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// An owned point-in-time view of a [`Histogram`]. Mergeable: merging
/// two snapshots is equivalent to having recorded both value streams
/// into one histogram.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Snapshot {
    buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl Snapshot {
    /// Fold `other` into `self` (bucket-wise addition).
    pub fn merge(&mut self, other: &Snapshot) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (acc, &c) in self.buckets.iter_mut().zip(&other.buckets) {
            *acc += c;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// Value at quantile `q` in `[0, 1]`: the upper bound of the first
    /// bucket whose cumulative count reaches `ceil(q * count)`, clamped
    /// to the exact observed max. Guaranteed `>=` the true quantile of
    /// the recorded stream and `<=` it plus one bucket width (6.25%
    /// relative error). Returns 0 for an empty snapshot.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_upper(idx).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }

    /// Mean of the recorded values, rounded down. 0 when empty.
    pub fn mean(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum / self.count
        }
    }
}

/// Stable per-thread shard assignment: threads get incrementing ids on
/// first use; the id mod the shard count picks the shard.
fn shard_of(n: usize) -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static ID: usize = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    ID.with(|id| *id % n.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_contiguous_and_exhaustive() {
        // Every boundary value lands in the bucket whose [lower, upper]
        // range contains it, and consecutive buckets tile the u64 line.
        for idx in 0..BUCKETS {
            let lo = bucket_lower(idx);
            let hi = bucket_upper(idx);
            assert!(lo <= hi, "bucket {idx}: lower {lo} > upper {hi}");
            assert_eq!(bucket_index(lo), idx, "lower bound of {idx}");
            assert_eq!(bucket_index(hi), idx, "upper bound of {idx}");
            if idx + 1 < BUCKETS {
                assert_eq!(hi + 1, bucket_lower(idx + 1), "gap after bucket {idx}");
            }
        }
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn relative_error_is_bounded() {
        for idx in 16..BUCKETS - 1 {
            let lo = bucket_lower(idx) as f64;
            let width = (bucket_upper(idx) - bucket_lower(idx) + 1) as f64;
            assert!(
                width / lo <= 1.0 / 16.0 + 1e-12,
                "bucket {idx}: width {width} lower {lo}"
            );
        }
    }

    #[test]
    fn records_and_reports_exact_small_values() {
        let h = Histogram::new();
        for v in [0u64, 1, 3, 7, 15] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 26);
        assert_eq!(s.max, 15);
        // Below 16 every bucket is exact, so percentiles are exact too.
        assert_eq!(s.percentile(1.0), 15);
        assert_eq!(s.p50(), 3);
    }

    #[test]
    fn merge_equals_record_all() {
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        let mut v = 0x2545_f491_4f6c_dd1du64;
        for i in 0..4000u64 {
            v ^= v << 13;
            v ^= v >> 7;
            v ^= v << 17;
            let sample = v % 1_000_000;
            if i % 2 == 0 {
                a.record(sample);
            } else {
                b.record(sample);
            }
            all.record(sample);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged, all.snapshot());
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.percentile(0.99), 0);
        assert_eq!(s.mean(), 0);
    }
}
