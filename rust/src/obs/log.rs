//! Structured, leveled logging (DESIGN.md §13).
//!
//! Replaces the ad-hoc `eprintln!` diagnostics scattered through the
//! library (lint rule L6 now forbids new ones outside `main.rs`). A log
//! call names a `target` (dotted module path like `hub.server`), a
//! human message, and zero or more `key=value` fields:
//!
//! ```text
//! obs::log::warn("hub.server", "slow reader disconnected", &[("addr", addr)]);
//! ```
//!
//! The process-wide level defaults to `info` and is set once at startup
//! from `--log-level error|warn|info|debug`. The sink is stderr by
//! default; tests swap in a capturing sink with [`capture`] (serialized
//! by a global lock so concurrent tests cannot observe each other's
//! records).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, RwLock};

/// Severity, ordered so that a numerically smaller level is more severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `--log-level` argument.
    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-wide maximum level that will be emitted.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Would a record at `level` currently be emitted?
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// One emitted log record.
#[derive(Clone, Debug)]
pub struct Record {
    pub level: Level,
    pub target: String,
    pub message: String,
    pub fields: Vec<(String, String)>,
}

impl Record {
    /// Render as a single line: `[warn] hub.server: message key=value`.
    /// Field values containing whitespace are debug-quoted.
    pub fn render(&self) -> String {
        let mut out = format!("[{}] {}: {}", self.level.name(), self.target, self.message);
        for (k, v) in &self.fields {
            if v.chars().any(char::is_whitespace) || v.is_empty() {
                out.push_str(&format!(" {k}={v:?}"));
            } else {
                out.push_str(&format!(" {k}={v}"));
            }
        }
        out
    }
}

enum Sink {
    Stderr,
    Capture(Arc<Mutex<Vec<Record>>>),
}

fn sink() -> &'static RwLock<Sink> {
    static SINK: OnceLock<RwLock<Sink>> = OnceLock::new();
    SINK.get_or_init(|| RwLock::new(Sink::Stderr))
}

/// Emit a record if `level` passes the filter.
pub fn log(level: Level, target: &str, message: &str, fields: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    let record = Record {
        level,
        target: target.to_string(),
        message: message.to_string(),
        fields: fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
    };
    let guard = sink().read().unwrap_or_else(|e| e.into_inner());
    match &*guard {
        // lint: allow(logging, reason = "this is the logger's own terminal sink")
        Sink::Stderr => eprintln!("{}", record.render()),
        Sink::Capture(buf) => {
            buf.lock().unwrap_or_else(|e| e.into_inner()).push(record);
        }
    }
}

pub fn error(target: &str, message: &str, fields: &[(&str, String)]) {
    log(Level::Error, target, message, fields);
}

pub fn warn(target: &str, message: &str, fields: &[(&str, String)]) {
    log(Level::Warn, target, message, fields);
}

pub fn info(target: &str, message: &str, fields: &[(&str, String)]) {
    log(Level::Info, target, message, fields);
}

pub fn debug(target: &str, message: &str, fields: &[(&str, String)]) {
    log(Level::Debug, target, message, fields);
}

/// A capturing sink for tests. While alive, all records (at `debug`
/// level and up) land in an in-memory buffer instead of stderr; drop
/// restores the previous level and the stderr sink. Captures are
/// serialized process-wide so concurrent tests don't interleave.
pub struct Capture {
    _serial: MutexGuard<'static, ()>,
    buf: Arc<Mutex<Vec<Record>>>,
    prev_level: u8,
}

/// Install a capturing sink; see [`Capture`].
pub fn capture() -> Capture {
    static SERIAL: Mutex<()> = Mutex::new(());
    let serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let buf = Arc::new(Mutex::new(Vec::new()));
    let prev_level = LEVEL.load(Ordering::Relaxed);
    LEVEL.store(Level::Debug as u8, Ordering::Relaxed);
    *sink().write().unwrap_or_else(|e| e.into_inner()) = Sink::Capture(buf.clone());
    Capture {
        _serial: serial,
        buf,
        prev_level,
    }
}

impl Capture {
    /// All records captured so far.
    pub fn records(&self) -> Vec<Record> {
        self.buf.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Drain and return the captured records.
    pub fn take(&self) -> Vec<Record> {
        std::mem::take(&mut *self.buf.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

impl Drop for Capture {
    fn drop(&mut self) {
        *sink().write().unwrap_or_else(|e| e.into_inner()) = Sink::Stderr;
        LEVEL.store(self.prev_level, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_sees_fields_and_respects_drop() {
        let cap = capture();
        warn("obs.test", "something happened", &[("k", "v".to_string())]);
        debug("obs.test", "fine detail", &[]);
        let recs = cap.take();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].level, Level::Warn);
        assert_eq!(recs[0].target, "obs.test");
        assert_eq!(recs[0].render(), "[warn] obs.test: something happened k=v");
        drop(cap);
        // After drop the sink is stderr again; this must not append to
        // the (already dropped) buffer — just exercising the path.
        info("obs.test", "post-drop", &[]);
    }

    #[test]
    fn level_filter_suppresses_below_threshold() {
        let cap = capture();
        set_level(Level::Warn);
        info("obs.test", "filtered", &[]);
        error("obs.test", "kept", &[]);
        set_level(Level::Debug);
        let recs = cap.take();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].message, "kept");
    }

    #[test]
    fn whitespace_values_are_quoted() {
        let r = Record {
            level: Level::Info,
            target: "t".into(),
            message: "m".into(),
            fields: vec![("a", "x y"), ("b", "z")]
                .into_iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
        };
        assert_eq!(r.render(), "[info] t: m a=\"x y\" b=z");
    }

    #[test]
    fn parse_round_trips_names() {
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(l.name()), Some(l));
        }
        assert_eq!(Level::parse("verbose"), None);
    }
}
