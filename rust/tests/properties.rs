//! Property-based tests over coordinator invariants (seeded sweeps via
//! `c3o::util::proptest` — the offline cache has no proptest crate).

use std::sync::Arc;

use c3o::cloud::Catalog;
use c3o::configurator::{select_scale_out, UserGoals};
use c3o::cv::{FitEngine, SelectionBudget};
use c3o::data::{Dataset, JobKind, RunRecord};
use c3o::linalg::Matrix;
use c3o::models::{C3oPredictor, RuntimeModel, TrainData};
use c3o::runtime::NativeBackend;
use c3o::util::erf::{confidence_multiplier, erf, erf_inv};
use c3o::util::prng::Pcg;
use c3o::util::proptest::{forall, forall_res};

fn world(rng: &mut Pcg, n: usize) -> TrainData {
    let mut rows = Vec::new();
    let mut y = Vec::new();
    for i in 0..n {
        let s = rng.range(2, 13) as f64;
        let (d, k) = if i % 3 == 0 {
            (20.0, 5.0)
        } else {
            (rng.range_f64(10.0, 30.0), rng.range(3, 10) as f64)
        };
        rows.push(vec![s, d, k]);
        y.push((1.0 / s + 0.02 * s) * (10.0 + 4.0 * d + 9.0 * k)
            * (1.0 + 0.03 * rng.normal()));
    }
    TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap()
}

#[test]
fn prop_erf_inverse_round_trip() {
    forall(
        "erf(erf_inv(x)) == x",
        300,
        |rng| rng.range_f64(-0.999, 0.999),
        |&x| (erf(erf_inv(x)) - x).abs() < 1e-9,
    );
}

#[test]
fn prop_confidence_multiplier_quantile_semantics() {
    // P(eps <= mu + m*sigma) == c for Gaussian residuals: check via
    // Monte-Carlo against the multiplier.
    forall_res(
        "multiplier is the c-quantile",
        20,
        |rng| (rng.range_f64(0.6, 0.99), rng.next_u64()),
        |&(c, seed)| {
            let m = confidence_multiplier(c);
            let mut rng = Pcg::seed(seed);
            let n = 20_000;
            let below = (0..n).filter(|_| rng.normal() <= m).count();
            let frac = below as f64 / n as f64;
            anyhow::ensure!((frac - c).abs() < 0.015, "c={c} frac={frac}");
            Ok(())
        },
    );
}

#[test]
fn prop_c3o_never_worse_than_all_candidates() {
    // The selection report's chosen MAPE is the min over candidates by
    // construction; verify over random worlds (guards regressions in the
    // scoring plumbing).
    forall_res(
        "C3O selection picks the argmin",
        15,
        |rng| {
            let n = rng.range(12, 40);
            world(rng, n)
        },
        |data| {
            let mut p = C3oPredictor::new(Arc::new(NativeBackend::new()));
            let report = p.fit(data)?;
            let min = report
                .scores
                .iter()
                .map(|(_, s)| s.mape)
                .fold(f64::INFINITY, f64::min);
            anyhow::ensure!((report.chosen_score.mape - min).abs() < 1e-12);
            Ok(())
        },
    );
}

#[test]
fn prop_selection_never_panics_on_degenerate_training_data() {
    // Constant-y, zero-y and single-machine (one scale-out) worlds used to
    // be able to panic selection via NaN MAPE in `partial_cmp(..).unwrap()`
    // or value-inferred fitted-state checks. An `Err` (all candidates
    // disqualified) is acceptable; a panic is the bug.
    forall_res(
        "selection survives degenerate data",
        18,
        |rng| {
            let kind = rng.range(0, 3);
            let n = rng.range(3, 30);
            let mut rows = Vec::new();
            let mut y = Vec::new();
            for _ in 0..n {
                let s = match kind {
                    2 => 4.0, // single machine count for every run
                    _ => rng.range(2, 13) as f64,
                };
                rows.push(vec![s, rng.range_f64(10.0, 30.0), rng.range(3, 10) as f64]);
                y.push(match kind {
                    0 => 42.0, // constant runtimes
                    1 => 0.0,  // zero runtimes
                    _ => rng.range_f64(1.0, 100.0),
                });
            }
            (kind, TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap())
        },
        |(_, data)| {
            // Serial reference engine...
            let mut p = C3oPredictor::new(Arc::new(NativeBackend::new()));
            if let Ok(report) = p.fit(data) {
                anyhow::ensure!(report.chosen_score.mape.is_finite());
                anyhow::ensure!(p.predict_one(&[4.0, 20.0, 5.0])?.is_finite());
            }
            // ...and the parallel engine with a point budget, so the task
            // pool, reduction walk and stratified sampler all see the
            // same degenerate inputs.
            let mut q = C3oPredictor::new(Arc::new(NativeBackend::new()));
            q.set_engine(FitEngine {
                threads: 4,
                budget: SelectionBudget { max_points: Some(12), ..SelectionBudget::default() },
            });
            if let Ok(report) = q.fit(data) {
                anyhow::ensure!(report.chosen_score.mape.is_finite());
                anyhow::ensure!(q.predict_one(&[4.0, 20.0, 5.0])?.is_finite());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scaleout_monotone_in_deadline() {
    // Looser deadlines can only keep or *lower* the chosen scale-out.
    let catalog = Catalog::aws_like();
    let mut p = C3oPredictor::new(Arc::new(NativeBackend::new()));
    let mut rng = Pcg::seed(0x5CA1E);
    let data = world(&mut rng, 60);
    p.fit(&data).unwrap();
    let input = c3o::sim::JobInput::new(JobKind::KMeans, 20.0, vec![5.0, 0.001]);

    forall_res(
        "scale-out monotone in deadline",
        40,
        |rng| {
            let d1 = rng.range_f64(30.0, 400.0);
            let d2 = d1 + rng.range_f64(1.0, 300.0);
            (d1, d2)
        },
        |&(tight, loose)| {
            let choose = |deadline: f64| {
                select_scale_out(
                    &catalog,
                    "m5.xlarge",
                    &p,
                    &input,
                    &UserGoals { deadline_s: Some(deadline), confidence: 0.9 },
                    0.0,
                    8.0,
                )
            };
            match (choose(tight), choose(loose)) {
                (Ok(a), Ok(b)) => {
                    anyhow::ensure!(
                        b.scale_out <= a.scale_out,
                        "loose {} > tight {}",
                        b.scale_out,
                        a.scale_out
                    );
                }
                (Err(_), Ok(_)) => {}  // tight infeasible, loose ok: fine
                (Ok(_), Err(e)) => anyhow::bail!("loose infeasible but tight ok: {e}"),
                (Err(_), Err(_)) => {} // both infeasible: fine
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dataset_tsv_round_trip() {
    forall_res(
        "dataset TSV round-trips",
        50,
        |rng| {
            let job = *rng.choose(&JobKind::ALL);
            let n = rng.range(1, 20);
            let mut ds = Dataset::new(job);
            for _ in 0..n {
                ds.push(RunRecord {
                    machine_type: format!("m{}.xlarge", rng.range(1, 9)),
                    scale_out: rng.range(1, 30) as u32,
                    data_size_gb: rng.range_f64(0.1, 50.0),
                    context: (0..job.context_features())
                        .map(|_| rng.range_f64(0.0001, 100.0))
                        .collect(),
                    runtime_s: rng.range_f64(1.0, 10_000.0),
                })
                .unwrap();
            }
            ds
        },
        |ds| {
            let table = ds.to_table()?;
            let text = table.to_text()?;
            let back = Dataset::from_table(ds.job, &c3o::util::tsv::Table::parse(&text)?)?;
            anyhow::ensure!(back.len() == ds.len());
            for (a, b) in ds.records.iter().zip(&back.records) {
                anyhow::ensure!(a.machine_type == b.machine_type);
                anyhow::ensure!(a.scale_out == b.scale_out);
                anyhow::ensure!((a.runtime_s - b.runtime_s).abs() < 1e-9);
                anyhow::ensure!((a.data_size_gb - b.data_size_gb).abs() < 1e-9);
                for (x, y) in a.context.iter().zip(&b.context) {
                    anyhow::ensure!((x - y).abs() < 1e-9);
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gbm_predictions_bounded_by_target_range() {
    // Squared-loss leaf means can never exceed the observed target range.
    forall_res(
        "GBM stays within target hull",
        20,
        |rng| {
            let n = rng.range(5, 50);
            (world(rng, n), rng.range_f64(1.0, 40.0), rng.range_f64(5.0, 35.0))
        },
        |(data, s, d)| {
            let mut m = c3o::models::Gbm::with_defaults();
            m.fit(data)?;
            let lo = data.y.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = data.y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let p = m.predict_one(&[*s, *d, 5.0])?;
            let slack = 1e-9 * hi.abs().max(1.0);
            anyhow::ensure!(
                p >= lo - slack && p <= hi + slack,
                "p={p} outside [{lo}, {hi}]"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_loo_is_permutation_invariant_for_ernest() {
    // Shuffling training rows must not change Ernest's LOO prediction for
    // a given (physical) point.
    forall_res(
        "Ernest LOO permutation-invariant",
        15,
        |rng| {
            let n = rng.range(6, 20);
            let data = world(rng, n);
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            (data, perm)
        },
        |(data, perm)| {
            let model = c3o::models::Ernest::new(Arc::new(NativeBackend::new()));
            let base = model.loo_predictions(data)?;
            let shuffled = data.subset(perm);
            let shuf = model.loo_predictions(&shuffled)?;
            for (pos, &orig) in perm.iter().enumerate() {
                anyhow::ensure!(
                    (shuf[pos] - base[orig]).abs() < 1e-6,
                    "row {orig}: {} vs {}",
                    shuf[pos],
                    base[orig]
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_frame_decoder_reassembles_every_byte_boundary_split() {
    use c3o::api::proto::FrameDecoder;

    // A fixed multi-frame stream with the awkward cases — an empty frame,
    // a CRLF-terminated frame, JSON punctuation — split exhaustively at
    // every byte boundary. Frames are pulled between the two feeds so the
    // partial-tail state is exercised, and the exact sequence must come
    // back regardless of where the cut lands.
    let frames = ["{\"v\":1,\"id\":7,\"op\":\"stats\"}", "", "crlf line", "tail"];
    let mut stream = Vec::new();
    for (i, f) in frames.iter().enumerate() {
        stream.extend_from_slice(f.as_bytes());
        if i == 2 {
            stream.push(b'\r');
        }
        stream.push(b'\n');
    }
    for cut in 0..=stream.len() {
        let mut d = FrameDecoder::default();
        d.feed(&stream[..cut]).unwrap();
        let mut out = Vec::new();
        while let Some(f) = d.next_frame() {
            out.push(f);
        }
        d.feed(&stream[cut..]).unwrap();
        while let Some(f) = d.next_frame() {
            out.push(f);
        }
        assert_eq!(out, frames, "split at byte {cut}");
        assert_eq!(d.buffered(), 0, "split at byte {cut}");
        assert!(!d.is_poisoned());
    }
}

#[test]
fn prop_frame_decoder_interleaved_connections_never_misframe() {
    use c3o::api::proto::FrameDecoder;

    // The reactor keeps one decoder per connection and feeds each whatever
    // read(2) produced, in arbitrary interleaving across connections. Each
    // decoder must emit exactly its own stream's frames, in order, holding
    // no more than one partial frame between feeds.
    forall_res(
        "interleaved chunked frames reassemble per connection",
        150,
        |rng| {
            let conns = rng.range(2, 4);
            let mut frames = Vec::new();
            let mut per_conn_chunks = Vec::new();
            for _ in 0..conns {
                let n = rng.range(1, 7);
                let fs: Vec<String> = (0..n)
                    .map(|_| {
                        // Printable ASCII: no '\n' or '\r' and valid UTF-8,
                        // so the round trip must be byte-exact.
                        let len = rng.range(0, 40);
                        (0..len).map(|_| (b' ' + rng.below(95) as u8) as char).collect()
                    })
                    .collect();
                let bytes: Vec<u8> = fs
                    .iter()
                    .flat_map(|f| f.bytes().chain(std::iter::once(b'\n')))
                    .collect();
                let mut chunks = Vec::new();
                let mut pos = 0;
                while pos < bytes.len() {
                    let take = rng.range(1, 8).min(bytes.len() - pos);
                    chunks.push(bytes[pos..pos + take].to_vec());
                    pos += take;
                }
                frames.push(fs);
                per_conn_chunks.push(chunks);
            }
            // Random order-preserving merge of the per-connection chunk
            // sequences (chunks of one connection never reorder).
            let mut cursors = vec![0usize; conns];
            let mut merged = Vec::new();
            loop {
                let alive: Vec<usize> = (0..conns)
                    .filter(|&c| cursors[c] < per_conn_chunks[c].len())
                    .collect();
                if alive.is_empty() {
                    break;
                }
                let c = *rng.choose(&alive);
                merged.push((c, per_conn_chunks[c][cursors[c]].clone()));
                cursors[c] += 1;
            }
            (frames, merged)
        },
        |(frames, merged)| {
            let mut decoders: Vec<FrameDecoder> =
                (0..frames.len()).map(|_| FrameDecoder::default()).collect();
            let mut got: Vec<Vec<String>> = vec![Vec::new(); frames.len()];
            for (conn, chunk) in merged {
                decoders[*conn].feed(chunk)?;
                while let Some(f) = decoders[*conn].next_frame() {
                    got[*conn].push(f);
                }
                // Once drained, only the partial tail remains (frames in
                // this test are at most 40 bytes long).
                anyhow::ensure!(decoders[*conn].buffered() <= 40);
            }
            anyhow::ensure!(&got == frames, "mis-framed: {got:?} != {frames:?}");
            Ok(())
        },
    );
}

#[test]
fn prop_frame_decoder_rejects_absurd_lengths_without_buffering() {
    use c3o::api::proto::FrameDecoder;

    // A peer claiming an absurdly long frame must be refused *before* the
    // bytes are copied in: `buffered()` stays at the pre-burst level, the
    // decoder poisons itself, and nothing is ever framed again.
    forall_res(
        "oversized frames are refused before they are buffered",
        120,
        |rng| {
            let max_frame = rng.range(4, 64);
            // A legitimate partial frame may already be sitting in the
            // buffer when the oversized burst arrives.
            let prefix_len = rng.below(max_frame + 1);
            let burst = max_frame + 1 - prefix_len + rng.below(4 * max_frame);
            let newline_terminated = rng.f64() < 0.5;
            (max_frame, prefix_len, burst, newline_terminated)
        },
        |&(max_frame, prefix_len, burst, newline_terminated)| {
            let mut d = FrameDecoder::new(max_frame);
            let prefix = vec![b'a'; prefix_len];
            d.feed(&prefix)?;
            anyhow::ensure!(d.buffered() == prefix_len);
            let mut bytes = vec![b'x'; burst];
            if newline_terminated {
                bytes.push(b'\n');
            }
            anyhow::ensure!(d.feed(&bytes).is_err(), "oversized burst was accepted");
            anyhow::ensure!(
                d.buffered() == prefix_len,
                "oversized bytes were buffered: {} > {prefix_len}",
                d.buffered()
            );
            anyhow::ensure!(d.is_poisoned());
            anyhow::ensure!(d.next_frame().is_none());
            anyhow::ensure!(d.feed(b"ok\n").is_err(), "poisoned decoder accepted bytes");
            Ok(())
        },
    );
}

#[test]
fn prop_wal_scan_survives_flips_and_truncations() {
    use c3o::storage::wal::{crc32, scan};

    // Hand-built frames (the writer's encoder is private): the framing
    // contract `[len u32 LE | crc32(payload) u32 LE | payload = revision
    // u64 LE + TSV]` is the on-disk format of DESIGN.md §9.
    fn frame(revision: u64, tsv: &str) -> Vec<u8> {
        let mut payload = revision.to_le_bytes().to_vec();
        payload.extend_from_slice(tsv.as_bytes());
        let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(&crc32(&payload).to_le_bytes());
        buf.extend_from_slice(&payload);
        buf
    }

    forall_res(
        "corrupt WAL yields exactly the intact prefix before the damage",
        400,
        |rng| {
            let n = rng.range(1, 8);
            let mut log = Vec::new();
            let mut ends = Vec::new();
            for rev in 1..=n as u64 {
                let mut tsv = String::from("machine_type\tscale_out\truntime_s\n");
                for row in 0..rng.range(1, 5) {
                    tsv.push_str(&format!(
                        "m5.xlarge\t{}\t{:.3}\n",
                        2 + row,
                        rng.range_f64(50.0, 500.0)
                    ));
                }
                log.extend_from_slice(&frame(rev, &tsv));
                ends.push(log.len());
            }
            let pos = rng.below(log.len());
            let truncate = rng.f64() < 0.5;
            let bit = rng.below(8) as u32;
            (log, ends, pos, truncate, bit)
        },
        |(log, ends, pos, truncate, bit)| {
            // Sanity: the undamaged log scans fully.
            anyhow::ensure!(scan(log).records.len() == ends.len());
            let damaged: Vec<u8> = if *truncate {
                log[..*pos].to_vec()
            } else {
                let mut d = log.clone();
                d[*pos] ^= 1u8 << bit;
                d
            };
            let out = scan(&damaged);
            // Exactly the frames wholly before the corruption point
            // survive: never a record at or past it (the crc catches
            // every single-bit flip; a truncated frame is torn), and
            // never fewer (earlier frames are untouched).
            let intact = ends.iter().filter(|&&e| e <= *pos).count();
            anyhow::ensure!(
                out.records.len() == intact,
                "scan yielded {} records, {} frames are intact before byte {}",
                out.records.len(),
                intact,
                pos
            );
            // The surviving prefix is contiguous from revision 1: nothing
            // was skipped or reordered.
            for (i, rec) in out.records.iter().enumerate() {
                anyhow::ensure!(rec.revision == i as u64 + 1);
            }
            anyhow::ensure!(out.valid_len <= damaged.len() as u64);
            anyhow::ensure!(out.torn == (out.valid_len < damaged.len() as u64));
            Ok(())
        },
    );
}

/// Real source files, as bytes — the fuzz corpus for the lint lexer
/// and scanner. Mutations of working Rust are exactly the malformed
/// input `c3o lint` sees mid-edit, so these files double as seeds.
fn lint_corpus() -> Vec<Vec<u8>> {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    ["analysis/lexer.rs", "api/proto.rs", "storage/wal.rs", "hub/server.rs"]
        .iter()
        .map(|rel| std::fs::read(root.join(rel)).unwrap())
        .collect()
}

/// Apply 1..=8 random byte-level mutations (bit flips, truncations,
/// deletions, insertions) and decode lossily — the lexer consumes
/// `&str`, so invalid UTF-8 arrives as replacement chars, same as it
/// would via `fs::read_to_string`'s lossy fallback in the scanner.
fn mutate(rng: &mut Pcg, base: &[u8]) -> String {
    let mut bytes = base.to_vec();
    for _ in 0..rng.range(1, 9) {
        if bytes.is_empty() {
            break;
        }
        let pos = rng.below(bytes.len());
        match rng.below(4) {
            0 => bytes[pos] ^= 1u8 << rng.below(8),
            1 => bytes.truncate(pos),
            2 => {
                bytes.remove(pos);
            }
            _ => bytes.insert(pos, rng.next_u64() as u8),
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

#[test]
fn prop_lexer_and_scanner_never_panic_on_mutated_sources() {
    use c3o::analysis::scanner::SourceFile;

    // The linter runs in CI against whatever is checked in — half-typed
    // strings, torn comments, stray quotes. Lexing and the structural
    // scan must degrade (odd tokens, fewer fns), never panic. The
    // property is the absence of a panic; the body only has to touch
    // the results.
    let corpus = lint_corpus();
    forall(
        "lexer + scanner survive byte mutations",
        250,
        |rng| {
            let base = rng.choose(&corpus).clone();
            mutate(rng, &base)
        },
        |src| {
            let sf = SourceFile::parse(
                std::path::PathBuf::from("fuzz.rs"),
                "fuzz/fuzz.rs".into(),
                src,
            );
            for f in &sf.fns {
                assert!(f.body_start <= f.body_end, "inverted fn span in `{}`", f.name);
                assert!(f.body_end < sf.tokens.len().max(1), "fn span past EOF");
            }
            true
        },
    );
}

#[test]
fn prop_token_and_comment_spans_tile_the_input() {
    use c3o::analysis::lexer::lex;

    // Spans are half-open char ranges. Sorted, they must be disjoint,
    // in-bounds, and leave only whitespace in the gaps — even on
    // mutated garbage. Every lint rule navigates by span; a hole or an
    // overlap silently corrupts taint ranges and allow-marker anchors.
    let corpus = lint_corpus();
    forall_res(
        "token + comment spans tile the input",
        250,
        |rng| {
            let base = rng.choose(&corpus).clone();
            mutate(rng, &base)
        },
        |src| {
            let chars: Vec<char> = src.chars().collect();
            let (toks, comments) = lex(src);
            let mut spans: Vec<(usize, usize)> = toks.iter().map(|t| t.span).collect();
            spans.extend(comments.iter().map(|c| c.span));
            spans.sort_unstable();
            let mut prev = 0usize;
            for (lo, hi) in spans {
                anyhow::ensure!(lo < hi && hi <= chars.len(), "bad span ({lo},{hi})");
                anyhow::ensure!(lo >= prev, "overlapping spans at {lo} (prev end {prev})");
                anyhow::ensure!(
                    chars[prev..lo].iter().all(|c| c.is_whitespace()),
                    "non-whitespace gap {prev}..{lo}"
                );
                prev = hi;
            }
            anyhow::ensure!(
                chars[prev..].iter().all(|c| c.is_whitespace()),
                "non-whitespace tail after {prev}"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_histogram_percentiles_within_bucket_error() {
    // The log-linear buckets guarantee: reported quantile >= the exact
    // order statistic, and overshoots it by at most one bucket width
    // (relative error 1/16, plus 1 for integer rounding).
    use c3o::obs::Histogram;
    forall_res(
        "histogram percentile error is bucket-bounded",
        40,
        |rng| {
            let n = rng.range(1, 500);
            (0..n)
                .map(|_| rng.next_u64() >> (4 + rng.below(56) as u32))
                .collect::<Vec<u64>>()
        },
        |values| {
            let h = Histogram::new();
            for &v in values {
                h.record(v);
            }
            let snap = h.snapshot();
            anyhow::ensure!(snap.count == values.len() as u64);
            let mut sorted = values.clone();
            sorted.sort_unstable();
            for q in [0.5, 0.9, 0.95, 0.99, 1.0] {
                let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
                let exact = sorted[rank - 1];
                let reported = snap.percentile(q);
                anyhow::ensure!(
                    reported >= exact,
                    "q={q}: reported {reported} < exact {exact}"
                );
                let bound = exact + exact / 16 + 1;
                anyhow::ensure!(
                    reported <= bound,
                    "q={q}: reported {reported} > bound {bound} (exact {exact})"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_histogram_merge_equals_record_all() {
    // Merging shard snapshots is lossless: any partition of a sample
    // into two histograms merges to exactly the record-all snapshot.
    use c3o::obs::Histogram;
    forall_res(
        "histogram merge is partition-invariant",
        30,
        |rng| {
            let n = rng.range(0, 300);
            (0..n)
                .map(|_| {
                    let v = rng.next_u64() >> (4 + rng.below(56) as u32);
                    (v, rng.below(2) == 0)
                })
                .collect::<Vec<(u64, bool)>>()
        },
        |values| {
            let (a, b, all) = (Histogram::new(), Histogram::new(), Histogram::new());
            for &(v, left) in values {
                all.record(v);
                let target = if left { &a } else { &b };
                target.record(v);
            }
            let mut merged = a.snapshot();
            merged.merge(&b.snapshot());
            anyhow::ensure!(merged == all.snapshot(), "merged snapshot diverged");
            Ok(())
        },
    );
}
