//! Fixture tests for `c3o lint` (`c3o::analysis`): each rule gets a
//! bad fixture that must fire and a good fixture that must stay silent,
//! plus a self-check pinning the project tree itself at zero findings.

use std::fs;
use std::path::{Path, PathBuf};

use c3o::analysis::{lint_dir, LintReport};

/// A throwaway source tree under the system temp dir.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(tag: &str) -> Self {
        let root = std::env::temp_dir().join(format!("c3o_lint_{}_{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&root).unwrap();
        Fixture { root }
    }

    fn file(&self, rel: &str, src: &str) -> &Self {
        let path = self.root.join(rel);
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(path, src).unwrap();
        self
    }

    fn lint(&self) -> LintReport {
        lint_dir(&self.root).unwrap()
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

fn rules_fired(report: &LintReport) -> Vec<&str> {
    report.findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------------
// L1 — lock order
// ---------------------------------------------------------------------------

#[test]
fn lock_order_inversion_fires() {
    let fx = Fixture::new("l1_bad");
    fx.file(
        "hub/repo.rs",
        r#"
use std::sync::{Mutex, RwLock};

pub fn inverted(wal: &Mutex<u32>, repos: &RwLock<u32>) {
    let w = wal.lock().unwrap();
    let r = repos.read().unwrap();
    drop(r);
    drop(w);
}
"#,
    );
    let report = fx.lint();
    assert!(
        rules_fired(&report).contains(&"lock_order"),
        "expected a lock_order finding, got: {:?}",
        report.findings
    );
    let f = report.findings.iter().find(|f| f.rule == "lock_order").unwrap();
    assert!(f.message.contains("inversion"), "message: {}", f.message);
    assert_eq!(f.file, "hub/repo.rs");
}

#[test]
fn lock_order_forward_edges_are_clean() {
    let fx = Fixture::new("l1_good");
    fx.file(
        "hub/repo.rs",
        r#"
use std::sync::{Mutex, RwLock};

pub fn forward(wal: &Mutex<u32>, repos: &RwLock<u32>) {
    let r = repos.read().unwrap();
    let w = wal.lock().unwrap();
    drop(w);
    drop(r);
}
"#,
    );
    let report = fx.lint();
    assert!(
        report.findings.is_empty(),
        "forward acquisition must be clean, got: {:?}",
        report.findings
    );
    assert!(
        report.lock_edges.iter().any(|e| e.from == "repos" && e.to == "wal"),
        "expected an observed repos -> wal edge, got: {:?}",
        report.lock_edges
    );
}

// ---------------------------------------------------------------------------
// L2 — panic-freedom on hot paths
// ---------------------------------------------------------------------------

#[test]
fn panic_freedom_fires_on_hot_path() {
    let fx = Fixture::new("l2_bad");
    fx.file(
        "api/proto.rs",
        r#"
pub fn first(v: &[u8]) -> u8 {
    v[0]
}

pub fn parse(s: &str) -> u32 {
    s.parse().unwrap()
}
"#,
    );
    let report = fx.lint();
    let fired = rules_fired(&report);
    assert_eq!(
        fired.iter().filter(|r| **r == "panics").count(),
        2,
        "expected indexing + unwrap findings, got: {:?}",
        report.findings
    );
}

#[test]
fn panic_freedom_ignores_cold_modules_and_allow_markers() {
    let fx = Fixture::new("l2_good");
    // Same panicky code in a non-hot module: out of scope for L2.
    fx.file(
        "models/fit.rs",
        r#"
pub fn parse(s: &str) -> u32 {
    s.parse().unwrap()
}
"#,
    );
    // Hot module, but every site is either structural or annotated.
    fx.file(
        "api/proto.rs",
        r#"
pub fn first(v: &[u8]) -> Option<u8> {
    v.first().copied()
}

pub fn parse(s: &str) -> u32 {
    // lint: allow(panics, reason = "fixture: demonstrating the escape hatch")
    s.parse().unwrap()
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

#[test]
fn reasonless_marker_is_itself_a_finding() {
    let fx = Fixture::new("marker_bad");
    fx.file(
        "api/proto.rs",
        r#"
pub fn parse(s: &str) -> u32 {
    // lint: allow(panics)
    s.parse().unwrap()
}
"#,
    );
    let report = fx.lint();
    let fired = rules_fired(&report);
    assert!(fired.contains(&"marker"), "got: {:?}", report.findings);
    // A reasonless marker does not suppress the underlying finding.
    assert!(fired.contains(&"panics"), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// L3 — unsafe audit
// ---------------------------------------------------------------------------

#[test]
fn unsafe_without_safety_comment_fires() {
    let fx = Fixture::new("l3_bad");
    fx.file(
        "hub/ffi.rs",
        r#"
pub fn raw() -> i32 {
    unsafe { ffi_call() }
}
"#,
    );
    let report = fx.lint();
    assert!(rules_fired(&report).contains(&"safety"), "got: {:?}", report.findings);
}

#[test]
fn unsafe_with_safety_comment_is_clean() {
    let fx = Fixture::new("l3_good");
    fx.file(
        "hub/ffi.rs",
        r#"
pub fn raw() -> i32 {
    // SAFETY: fixture — ffi_call has no preconditions.
    unsafe { ffi_call() }
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// L4 — durability discipline in storage/
// ---------------------------------------------------------------------------

#[test]
fn rename_without_sync_dir_fires_in_storage() {
    let fx = Fixture::new("l4_bad");
    fx.file(
        "storage/publish.rs",
        r#"
use std::fs;
use std::path::Path;

pub fn publish(a: &Path, b: &Path) -> std::io::Result<()> {
    fs::rename(a, b)
}
"#,
    );
    let report = fx.lint();
    assert!(rules_fired(&report).contains(&"durability"), "got: {:?}", report.findings);
}

#[test]
fn rename_paired_with_sync_dir_is_clean() {
    let fx = Fixture::new("l4_good");
    fx.file(
        "storage/publish.rs",
        r#"
use std::fs;
use std::path::Path;

pub fn publish(a: &Path, b: &Path) -> std::io::Result<()> {
    fs::rename(a, b)?;
    sync_dir(b)?;
    Ok(())
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// L5 — protocol exhaustiveness
// ---------------------------------------------------------------------------

const PROTO_PARTIAL: &str = r#"
impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Predict => "predict",
            Op::Submit => "submit",
        }
    }

    pub fn decode(s: &str) -> Option<Op> {
        match s {
            "predict" => Some(Op::Predict),
            _ => None,
        }
    }
}
"#;

const SERVICE_PARTIAL: &str = r#"
pub fn dispatch(op: &Op) -> u32 {
    match op {
        Op::Predict => 1,
        _ => 0,
    }
}
"#;

const CLIENT_PARTIAL: &str = r#"
pub fn call() -> Op {
    Op::Predict
}
"#;

#[test]
fn half_plumbed_op_fires_three_ways() {
    let fx = Fixture::new("l5_bad");
    fx.file("api/proto.rs", PROTO_PARTIAL)
        .file("api/service.rs", SERVICE_PARTIAL)
        .file("hub/client.rs", CLIENT_PARTIAL);
    let report = fx.lint();
    let msgs: Vec<&str> = report
        .findings
        .iter()
        .filter(|f| f.rule == "protocol")
        .map(|f| f.message.as_str())
        .collect();
    assert_eq!(msgs.len(), 3, "got: {:?}", report.findings);
    assert!(msgs.iter().any(|m| m.contains("never matched in `Op::decode`")));
    assert!(msgs.iter().any(|m| m.contains("not dispatched")));
    assert!(msgs.iter().any(|m| m.contains("not exercised by `HubClient`")));
}

#[test]
fn fully_plumbed_ops_are_clean() {
    let fx = Fixture::new("l5_good");
    fx.file(
        "api/proto.rs",
        r#"
impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Predict => "predict",
            Op::Submit => "submit",
        }
    }

    pub fn decode(s: &str) -> Option<Op> {
        match s {
            "predict" => Some(Op::Predict),
            "submit" => Some(Op::Submit),
            _ => None,
        }
    }
}
"#,
    )
    .file(
        "api/service.rs",
        r#"
pub fn dispatch(op: &Op) -> u32 {
    match op {
        Op::Predict => 1,
        Op::Submit => 2,
    }
}
"#,
    )
    .file(
        "hub/client.rs",
        r#"
pub fn predict() -> Op {
    Op::Predict
}

pub fn submit() -> Op {
    Op::Submit
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// L6 — logging discipline
// ---------------------------------------------------------------------------

#[test]
fn bare_eprintln_in_library_code_fires() {
    let fx = Fixture::new("l6_bad");
    fx.file(
        "hub/server.rs",
        r#"
pub fn report(e: &str) {
    eprintln!("[hub] something failed: {e}");
}
"#,
    );
    let report = fx.lint();
    let f = report
        .findings
        .iter()
        .find(|f| f.rule == "logging")
        .unwrap_or_else(|| panic!("expected a logging finding, got: {:?}", report.findings));
    assert_eq!(f.file, "hub/server.rs");
    assert!(f.message.contains("eprintln"), "message: {}", f.message);
}

#[test]
fn eprintln_is_exempt_in_main_tests_and_marked_sites() {
    let fx = Fixture::new("l6_good");
    // The CLI's terminal output is its interface.
    fx.file(
        "main.rs",
        r#"
fn main() {
    eprintln!("usage: c3o <cmd>");
}
"#,
    );
    // Test modules may print freely.
    fx.file(
        "eval/report.rs",
        r#"
pub fn quiet() {}

#[cfg(test)]
mod tests {
    #[test]
    fn prints() {
        eprintln!("debugging a test");
    }
}
"#,
    );
    // A justified terminal sink (like the logger's own) is allowed.
    fx.file(
        "obs/log.rs",
        r#"
pub fn emit(line: &str) {
    // lint: allow(logging, reason = "fixture: the logger's own terminal sink")
    eprintln!("{line}");
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// L7 — taint tracking for wire-derived values
// ---------------------------------------------------------------------------

#[test]
fn unvalidated_wire_length_fires() {
    let fx = Fixture::new("l7_bad");
    fx.file(
        "storage/wal.rs",
        r#"
use std::io::Read;

pub fn read_frame(f: &mut std::fs::File) -> std::io::Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    f.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr) as usize;
    let mut buf = Vec::with_capacity(len);
    buf.resize(len, 0);
    Ok(buf)
}
"#,
    );
    let report = fx.lint();
    let f = report
        .findings
        .iter()
        .find(|f| f.rule == "taint")
        .unwrap_or_else(|| panic!("expected a taint finding, got: {:?}", report.findings));
    assert!(f.message.contains("`len`"), "message: {}", f.message);
    assert!(
        report.taint_flows.iter().any(|fl| fl.var == "len" && fl.status == "flagged"),
        "expected a flagged flow for `len`, got: {:?}",
        report.taint_flows
    );
}

#[test]
fn bounds_checked_wire_length_is_clean() {
    let fx = Fixture::new("l7_good");
    fx.file(
        "storage/wal.rs",
        r#"
use std::io::Read;

const MAX_FRAME: usize = 1 << 20;

pub fn read_frame(f: &mut std::fs::File) -> std::io::Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    f.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "oversized"));
    }
    let mut buf = Vec::with_capacity(len);
    buf.resize(len, 0);
    Ok(buf)
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
    // The flow is still traced — as validated, with both line anchors.
    let fl = report
        .taint_flows
        .iter()
        .find(|fl| fl.var == "len")
        .unwrap_or_else(|| panic!("expected a traced flow for `len`: {:?}", report.taint_flows));
    assert_eq!(fl.status, "validated");
    assert!(fl.validated_line.is_some() && fl.sink_line.is_some());
}

#[test]
fn taint_ignores_out_of_scope_modules() {
    let fx = Fixture::new("l7_scope");
    // Identical code outside the wire-facing modules: not L7's business.
    fx.file(
        "eval/loader.rs",
        r#"
use std::io::Read;

pub fn read_frame(f: &mut std::fs::File) -> std::io::Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    f.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr) as usize;
    let mut buf = Vec::with_capacity(len);
    buf.resize(len, 0);
    Ok(buf)
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// L8 — durability ordering automaton
// ---------------------------------------------------------------------------

#[test]
fn publish_before_fsync_fires() {
    let fx = Fixture::new("l8_bad");
    fx.file(
        "storage/commit.rs",
        r#"
pub fn commit(w: &mut Wal, rec: &[u8]) -> std::io::Result<()> {
    w.append(rec)?;
    publish(rec);
    w.sync()?;
    Ok(())
}
"#,
    );
    let report = fx.lint();
    let f = report
        .findings
        .iter()
        .find(|f| f.rule == "ordering")
        .unwrap_or_else(|| panic!("expected an ordering finding, got: {:?}", report.findings));
    assert!(f.message.contains("not yet be fsynced"), "message: {}", f.message);
}

#[test]
fn append_sync_publish_is_clean() {
    let fx = Fixture::new("l8_good");
    fx.file(
        "storage/commit.rs",
        r#"
pub fn commit(w: &mut Wal, rec: &[u8]) -> std::io::Result<()> {
    w.append(rec)?;
    w.sync()?;
    publish(rec);
    Ok(())
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

#[test]
fn ack_before_append_fires() {
    let fx = Fixture::new("l8_ack");
    fx.file(
        "storage/commit.rs",
        r#"
pub fn submit(w: &mut Wal, rec: &[u8]) -> std::io::Result<()> {
    ack(7);
    w.append(rec)?;
    w.sync()?;
    Ok(())
}
"#,
    );
    let report = fx.lint();
    let f = report
        .findings
        .iter()
        .find(|f| f.rule == "ordering")
        .unwrap_or_else(|| panic!("expected an ordering finding, got: {:?}", report.findings));
    assert!(f.message.contains("may precede the WAL append"), "message: {}", f.message);
}

// ---------------------------------------------------------------------------
// L9 — allocation-free hot paths
// ---------------------------------------------------------------------------

#[test]
fn allocation_in_registered_hot_fn_fires() {
    let fx = Fixture::new("l9_bad");
    fx.file(
        "hub/server.rs",
        r#"
impl Reactor {
    fn tick(&mut self) {
        let buf: Vec<u8> = Vec::new();
        drop(buf);
    }

    fn setup(&mut self) {
        let buf: Vec<u8> = Vec::new();
        drop(buf);
    }
}
"#,
    );
    let report = fx.lint();
    let hits: Vec<_> = report.findings.iter().filter(|f| f.rule == "alloc_hot").collect();
    // `tick` is registered hot; `setup` is a cold path and allocates freely.
    assert_eq!(hits.len(), 1, "got: {:?}", report.findings);
    assert!(hits[0].message.contains("`tick`"), "message: {}", hits[0].message);
}

#[test]
fn alloc_hot_marker_suppresses() {
    let fx = Fixture::new("l9_good");
    fx.file(
        "hub/server.rs",
        r#"
impl Reactor {
    fn tick(&mut self) {
        // lint: allow(alloc_hot, reason = "fixture: demonstrating the escape hatch")
        let buf: Vec<u8> = Vec::new();
        drop(buf);
    }
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// Output formats
// ---------------------------------------------------------------------------

#[test]
fn json_report_round_trips() {
    let fx = Fixture::new("fmt_json");
    fx.file(
        "hub/repo.rs",
        r#"
use std::sync::{Mutex, RwLock};

pub fn forward(wal: &Mutex<u32>, repos: &RwLock<u32>) {
    let r = repos.read().unwrap();
    let w = wal.lock().unwrap();
    drop(w);
    drop(r);
}
"#,
    );
    let report = fx.lint();
    let text = c3o::analysis::render_json(&report, &fx.root);
    let doc = c3o::util::json::Json::parse(&text).unwrap();
    assert_eq!(doc.get("clean").and_then(|v| v.as_bool()), Some(true));
    let edges = doc.get("lock_edges").and_then(|v| v.as_arr()).unwrap();
    assert!(
        edges.iter().any(|e| {
            e.get("from").and_then(|v| v.as_str()) == Some("repos")
                && e.get("to").and_then(|v| v.as_str()) == Some("wal")
        }),
        "expected a repos -> wal edge in: {text}"
    );
}

#[test]
fn dot_output_renders_the_lock_dag() {
    let fx = Fixture::new("fmt_dot");
    fx.file(
        "hub/repo.rs",
        r#"
use std::sync::{Mutex, RwLock};

pub fn forward(wal: &Mutex<u32>, repos: &RwLock<u32>) {
    let r = repos.read().unwrap();
    let w = wal.lock().unwrap();
    drop(w);
    drop(r);
}
"#,
    );
    let report = fx.lint();
    let dot = c3o::analysis::render_dot(&report);
    assert!(dot.starts_with("digraph lock_order {"), "got: {dot}");
    assert!(dot.contains("repos -> wal;"), "got: {dot}");
    assert!(!dot.contains("color=red"), "forward edge drawn as inverted: {dot}");
}

// ---------------------------------------------------------------------------
// Interprocedural propagation
// ---------------------------------------------------------------------------

#[test]
fn lock_inversion_through_a_call_chain_fires() {
    let fx = Fixture::new("l1_deep");
    // wal held -> helper() -> deeper() -> repos: a 2-deep inversion the
    // one-level propagation of lint v1 could not see.
    fx.file(
        "hub/repo.rs",
        r#"
use std::sync::{Mutex, RwLock};

pub struct HubState {
    wal: Mutex<u32>,
    repos: RwLock<u32>,
}

impl HubState {
    pub fn outer(&self) {
        let w = self.wal.lock().unwrap();
        self.helper();
        drop(w);
    }

    pub fn helper(&self) {
        self.deeper();
    }

    pub fn deeper(&self) {
        let r = self.repos.read().unwrap();
        drop(r);
    }
}
"#,
    );
    let report = fx.lint();
    let f = report
        .findings
        .iter()
        .find(|f| f.rule == "lock_order")
        .unwrap_or_else(|| panic!("expected a lock_order finding, got: {:?}", report.findings));
    assert!(
        f.message.contains("via call to `helper -> deeper`"),
        "expected the call chain in: {}",
        f.message
    );
}

// ---------------------------------------------------------------------------
// Test-code exemption
// ---------------------------------------------------------------------------

#[test]
fn test_modules_are_exempt_from_hot_path_rules() {
    let fx = Fixture::new("test_exempt");
    fx.file(
        "api/proto.rs",
        r#"
pub fn first(v: &[u8]) -> Option<u8> {
    v.first().copied()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_here_is_fine() {
        let v = vec![1u8];
        assert_eq!(v[0], 1);
        let _ = "7".parse::<u32>().unwrap();
    }
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// Self-check: the project tree itself must be clean
// ---------------------------------------------------------------------------

#[test]
fn project_tree_is_lint_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let report = lint_dir(&src).unwrap();
    assert!(
        report.findings.is_empty(),
        "rust/src must stay lint-clean; findings:\n{}",
        report
            .findings
            .iter()
            .map(|f| format!("  {}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.files_scanned > 50, "scanned only {} files", report.files_scanned);
    // The analyzer is live, not vacuous: the tree's real forward lock
    // edges (submit_lock -> wal, fit_gate -> cache_stripe, ...) show up.
    assert!(
        !report.lock_edges.is_empty(),
        "expected observed lock-order edges in the project tree"
    );
    // Full-depth propagation is active: at least one edge was found
    // through a call rather than at a literal acquisition site.
    assert!(
        report.lock_edges.iter().any(|e| e.via.is_some()),
        "expected at least one interprocedural lock edge"
    );
    // And the taint engine traced the real wire values (frame lengths,
    // revisions, payload buffers) even though none of them fire.
    assert!(
        !report.taint_flows.is_empty(),
        "expected traced taint flows in wal.rs / proto.rs / transport.rs"
    );
    assert!(
        report.taint_flows.iter().any(|fl| fl.status == "validated"),
        "expected at least one validated wire flow, got: {:?}",
        report.taint_flows
    );
}
