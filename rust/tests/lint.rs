//! Fixture tests for `c3o lint` (`c3o::analysis`): each rule gets a
//! bad fixture that must fire and a good fixture that must stay silent,
//! plus a self-check pinning the project tree itself at zero findings.

use std::fs;
use std::path::{Path, PathBuf};

use c3o::analysis::{lint_dir, LintReport};

/// A throwaway source tree under the system temp dir.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(tag: &str) -> Self {
        let root = std::env::temp_dir().join(format!("c3o_lint_{}_{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&root).unwrap();
        Fixture { root }
    }

    fn file(&self, rel: &str, src: &str) -> &Self {
        let path = self.root.join(rel);
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(path, src).unwrap();
        self
    }

    fn lint(&self) -> LintReport {
        lint_dir(&self.root).unwrap()
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

fn rules_fired(report: &LintReport) -> Vec<&str> {
    report.findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------------------
// L1 — lock order
// ---------------------------------------------------------------------------

#[test]
fn lock_order_inversion_fires() {
    let fx = Fixture::new("l1_bad");
    fx.file(
        "hub/repo.rs",
        r#"
use std::sync::{Mutex, RwLock};

pub fn inverted(wal: &Mutex<u32>, repos: &RwLock<u32>) {
    let w = wal.lock().unwrap();
    let r = repos.read().unwrap();
    drop(r);
    drop(w);
}
"#,
    );
    let report = fx.lint();
    assert!(
        rules_fired(&report).contains(&"lock_order"),
        "expected a lock_order finding, got: {:?}",
        report.findings
    );
    let f = report.findings.iter().find(|f| f.rule == "lock_order").unwrap();
    assert!(f.message.contains("inversion"), "message: {}", f.message);
    assert_eq!(f.file, "hub/repo.rs");
}

#[test]
fn lock_order_forward_edges_are_clean() {
    let fx = Fixture::new("l1_good");
    fx.file(
        "hub/repo.rs",
        r#"
use std::sync::{Mutex, RwLock};

pub fn forward(wal: &Mutex<u32>, repos: &RwLock<u32>) {
    let r = repos.read().unwrap();
    let w = wal.lock().unwrap();
    drop(w);
    drop(r);
}
"#,
    );
    let report = fx.lint();
    assert!(
        report.findings.is_empty(),
        "forward acquisition must be clean, got: {:?}",
        report.findings
    );
    assert!(
        report.lock_edges.iter().any(|e| e.from == "repos" && e.to == "wal"),
        "expected an observed repos -> wal edge, got: {:?}",
        report.lock_edges
    );
}

// ---------------------------------------------------------------------------
// L2 — panic-freedom on hot paths
// ---------------------------------------------------------------------------

#[test]
fn panic_freedom_fires_on_hot_path() {
    let fx = Fixture::new("l2_bad");
    fx.file(
        "api/proto.rs",
        r#"
pub fn first(v: &[u8]) -> u8 {
    v[0]
}

pub fn parse(s: &str) -> u32 {
    s.parse().unwrap()
}
"#,
    );
    let report = fx.lint();
    let fired = rules_fired(&report);
    assert_eq!(
        fired.iter().filter(|r| **r == "panics").count(),
        2,
        "expected indexing + unwrap findings, got: {:?}",
        report.findings
    );
}

#[test]
fn panic_freedom_ignores_cold_modules_and_allow_markers() {
    let fx = Fixture::new("l2_good");
    // Same panicky code in a non-hot module: out of scope for L2.
    fx.file(
        "models/fit.rs",
        r#"
pub fn parse(s: &str) -> u32 {
    s.parse().unwrap()
}
"#,
    );
    // Hot module, but every site is either structural or annotated.
    fx.file(
        "api/proto.rs",
        r#"
pub fn first(v: &[u8]) -> Option<u8> {
    v.first().copied()
}

pub fn parse(s: &str) -> u32 {
    // lint: allow(panics, reason = "fixture: demonstrating the escape hatch")
    s.parse().unwrap()
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

#[test]
fn reasonless_marker_is_itself_a_finding() {
    let fx = Fixture::new("marker_bad");
    fx.file(
        "api/proto.rs",
        r#"
pub fn parse(s: &str) -> u32 {
    // lint: allow(panics)
    s.parse().unwrap()
}
"#,
    );
    let report = fx.lint();
    let fired = rules_fired(&report);
    assert!(fired.contains(&"marker"), "got: {:?}", report.findings);
    // A reasonless marker does not suppress the underlying finding.
    assert!(fired.contains(&"panics"), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// L3 — unsafe audit
// ---------------------------------------------------------------------------

#[test]
fn unsafe_without_safety_comment_fires() {
    let fx = Fixture::new("l3_bad");
    fx.file(
        "hub/ffi.rs",
        r#"
pub fn raw() -> i32 {
    unsafe { ffi_call() }
}
"#,
    );
    let report = fx.lint();
    assert!(rules_fired(&report).contains(&"safety"), "got: {:?}", report.findings);
}

#[test]
fn unsafe_with_safety_comment_is_clean() {
    let fx = Fixture::new("l3_good");
    fx.file(
        "hub/ffi.rs",
        r#"
pub fn raw() -> i32 {
    // SAFETY: fixture — ffi_call has no preconditions.
    unsafe { ffi_call() }
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// L4 — durability discipline in storage/
// ---------------------------------------------------------------------------

#[test]
fn rename_without_sync_dir_fires_in_storage() {
    let fx = Fixture::new("l4_bad");
    fx.file(
        "storage/publish.rs",
        r#"
use std::fs;
use std::path::Path;

pub fn publish(a: &Path, b: &Path) -> std::io::Result<()> {
    fs::rename(a, b)
}
"#,
    );
    let report = fx.lint();
    assert!(rules_fired(&report).contains(&"durability"), "got: {:?}", report.findings);
}

#[test]
fn rename_paired_with_sync_dir_is_clean() {
    let fx = Fixture::new("l4_good");
    fx.file(
        "storage/publish.rs",
        r#"
use std::fs;
use std::path::Path;

pub fn publish(a: &Path, b: &Path) -> std::io::Result<()> {
    fs::rename(a, b)?;
    sync_dir(b)?;
    Ok(())
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// L5 — protocol exhaustiveness
// ---------------------------------------------------------------------------

const PROTO_PARTIAL: &str = r#"
impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Predict => "predict",
            Op::Submit => "submit",
        }
    }

    pub fn decode(s: &str) -> Option<Op> {
        match s {
            "predict" => Some(Op::Predict),
            _ => None,
        }
    }
}
"#;

const SERVICE_PARTIAL: &str = r#"
pub fn dispatch(op: &Op) -> u32 {
    match op {
        Op::Predict => 1,
        _ => 0,
    }
}
"#;

const CLIENT_PARTIAL: &str = r#"
pub fn call() -> Op {
    Op::Predict
}
"#;

#[test]
fn half_plumbed_op_fires_three_ways() {
    let fx = Fixture::new("l5_bad");
    fx.file("api/proto.rs", PROTO_PARTIAL)
        .file("api/service.rs", SERVICE_PARTIAL)
        .file("hub/client.rs", CLIENT_PARTIAL);
    let report = fx.lint();
    let msgs: Vec<&str> = report
        .findings
        .iter()
        .filter(|f| f.rule == "protocol")
        .map(|f| f.message.as_str())
        .collect();
    assert_eq!(msgs.len(), 3, "got: {:?}", report.findings);
    assert!(msgs.iter().any(|m| m.contains("never matched in `Op::decode`")));
    assert!(msgs.iter().any(|m| m.contains("not dispatched")));
    assert!(msgs.iter().any(|m| m.contains("not exercised by `HubClient`")));
}

#[test]
fn fully_plumbed_ops_are_clean() {
    let fx = Fixture::new("l5_good");
    fx.file(
        "api/proto.rs",
        r#"
impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Predict => "predict",
            Op::Submit => "submit",
        }
    }

    pub fn decode(s: &str) -> Option<Op> {
        match s {
            "predict" => Some(Op::Predict),
            "submit" => Some(Op::Submit),
            _ => None,
        }
    }
}
"#,
    )
    .file(
        "api/service.rs",
        r#"
pub fn dispatch(op: &Op) -> u32 {
    match op {
        Op::Predict => 1,
        Op::Submit => 2,
    }
}
"#,
    )
    .file(
        "hub/client.rs",
        r#"
pub fn predict() -> Op {
    Op::Predict
}

pub fn submit() -> Op {
    Op::Submit
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// L6 — logging discipline
// ---------------------------------------------------------------------------

#[test]
fn bare_eprintln_in_library_code_fires() {
    let fx = Fixture::new("l6_bad");
    fx.file(
        "hub/server.rs",
        r#"
pub fn report(e: &str) {
    eprintln!("[hub] something failed: {e}");
}
"#,
    );
    let report = fx.lint();
    let f = report
        .findings
        .iter()
        .find(|f| f.rule == "logging")
        .unwrap_or_else(|| panic!("expected a logging finding, got: {:?}", report.findings));
    assert_eq!(f.file, "hub/server.rs");
    assert!(f.message.contains("eprintln"), "message: {}", f.message);
}

#[test]
fn eprintln_is_exempt_in_main_tests_and_marked_sites() {
    let fx = Fixture::new("l6_good");
    // The CLI's terminal output is its interface.
    fx.file(
        "main.rs",
        r#"
fn main() {
    eprintln!("usage: c3o <cmd>");
}
"#,
    );
    // Test modules may print freely.
    fx.file(
        "eval/report.rs",
        r#"
pub fn quiet() {}

#[cfg(test)]
mod tests {
    #[test]
    fn prints() {
        eprintln!("debugging a test");
    }
}
"#,
    );
    // A justified terminal sink (like the logger's own) is allowed.
    fx.file(
        "obs/log.rs",
        r#"
pub fn emit(line: &str) {
    // lint: allow(logging, reason = "fixture: the logger's own terminal sink")
    eprintln!("{line}");
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// Test-code exemption
// ---------------------------------------------------------------------------

#[test]
fn test_modules_are_exempt_from_hot_path_rules() {
    let fx = Fixture::new("test_exempt");
    fx.file(
        "api/proto.rs",
        r#"
pub fn first(v: &[u8]) -> Option<u8> {
    v.first().copied()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_here_is_fine() {
        let v = vec![1u8];
        assert_eq!(v[0], 1);
        let _ = "7".parse::<u32>().unwrap();
    }
}
"#,
    );
    let report = fx.lint();
    assert!(report.findings.is_empty(), "got: {:?}", report.findings);
}

// ---------------------------------------------------------------------------
// Self-check: the project tree itself must be clean
// ---------------------------------------------------------------------------

#[test]
fn project_tree_is_lint_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let report = lint_dir(&src).unwrap();
    assert!(
        report.findings.is_empty(),
        "rust/src must stay lint-clean; findings:\n{}",
        report
            .findings
            .iter()
            .map(|f| format!("  {}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.files_scanned > 50, "scanned only {} files", report.files_scanned);
    // The analyzer is live, not vacuous: the tree's real forward lock
    // edges (submit_lock -> wal, fit_gate -> cache_stripe, ...) show up.
    assert!(
        !report.lock_edges.is_empty(),
        "expected observed lock-order edges in the project tree"
    );
}
