//! Integration: PJRT engine (AOT artifacts) vs native backend parity.
//!
//! The artifacts compute in f32 with fixed padded shapes and iterative
//! NNLS; the native backend computes in f64 with exact solvers. Parity is
//! therefore approximate — tolerances below reflect f32 Gram conditioning,
//! and the *predictions* (what the models actually consume) are compared
//! tighter than the raw coefficients.
//!
//! Requires `make artifacts` plus a build with the `pjrt` feature; when
//! either is missing the tests skip (with a note) instead of failing —
//! the native backend is the only fit path in that configuration.

use std::sync::Arc;

use c3o::linalg::Matrix;
use c3o::models::{Bom, Ernest, RuntimeModel, TrainData};
use c3o::runtime::{Engine, FitBackend, NativeBackend};
use c3o::util::prng::Pcg;

fn engine() -> Option<Arc<Engine>> {
    static ONCE: std::sync::OnceLock<Option<Arc<Engine>>> = std::sync::OnceLock::new();
    ONCE.get_or_init(|| match Engine::load_default() {
        Ok(e) => Some(Arc::new(e)),
        Err(e) => {
            eprintln!("[runtime_parity] skipping: PJRT engine unavailable ({e:#})");
            None
        }
    })
    .clone()
}

/// A well-scaled random ridge problem with LOO-style masks.
fn problem(seed: u64, n: usize, f: usize, b: usize) -> (Matrix, Vec<f64>, Matrix) {
    let mut rng = Pcg::seed(seed);
    let rows: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..f).map(|_| rng.f64() * 2.0 - 0.5).collect())
        .collect();
    let beta: Vec<f64> = (0..f).map(|_| rng.f64() * 3.0).collect();
    let y: Vec<f64> = rows
        .iter()
        .map(|r| {
            r.iter().zip(&beta).map(|(a, b)| a * b).sum::<f64>() + 0.01 * rng.normal()
        })
        .collect();
    let x = Matrix::from_rows(&rows).unwrap();
    let mut w = Matrix::zeros(b, n);
    for bi in 0..b {
        for j in 0..n {
            w[(bi, j)] = 1.0;
        }
        w[(bi, bi % n)] = 0.0; // LOO-ish masks
    }
    (x, y, w)
}

#[test]
fn ols_predictions_agree() {
    let Some(eng) = engine() else { return };
    let native = NativeBackend::new();
    for seed in [1u64, 2, 3] {
        let (x, y, w) = problem(seed, 40, 5, 16);
        // MIN_LAM on the engine path is 1e-4; use the same for parity.
        let (_, p_e) = eng.ols_batch(&x, &y, &w, 1e-4).unwrap();
        let (_, p_n) = native.ols_batch(&x, &y, &w, 1e-4).unwrap();
        let scale = y.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        assert!(
            p_e.max_abs_diff(&p_n) < 2e-3 * scale.max(1.0),
            "seed {seed}: diff {}",
            p_e.max_abs_diff(&p_n)
        );
    }
}

#[test]
fn nnls_predictions_agree() {
    let Some(eng) = engine() else { return };
    let native = NativeBackend::new();
    for seed in [4u64, 5] {
        let (x, y, w) = problem(seed, 32, 4, 8);
        let (t_e, p_e) = eng.nnls_batch(&x, &y, &w, 1e-4).unwrap();
        let (_, p_n) = native.nnls_batch(&x, &y, &w, 1e-4).unwrap();
        // Coefficients must be nonnegative on both paths.
        for v in t_e.data() {
            assert!(*v >= -1e-6, "negative NNLS coefficient {v}");
        }
        let scale = y.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        assert!(
            p_e.max_abs_diff(&p_n) < 2e-2 * scale.max(1.0),
            "seed {seed}: diff {}",
            p_e.max_abs_diff(&p_n)
        );
    }
}

#[test]
fn predict_grid_agrees() {
    let Some(eng) = engine() else { return };
    let native = NativeBackend::new();
    let mut rng = Pcg::seed(6);
    let theta = Matrix::from_rows(
        &(0..8)
            .map(|_| (0..4).map(|_| rng.f64()).collect::<Vec<_>>())
            .collect::<Vec<_>>(),
    )
    .unwrap();
    let xq = Matrix::from_rows(
        &(0..10)
            .map(|_| (0..4).map(|_| rng.f64() * 5.0).collect::<Vec<_>>())
            .collect::<Vec<_>>(),
    )
    .unwrap();
    let a = eng.predict_grid(&theta, &xq).unwrap();
    let b = native.predict_grid(&theta, &xq).unwrap();
    assert!(a.max_abs_diff(&b) < 1e-4, "diff {}", a.max_abs_diff(&b));
}

#[test]
fn oversized_problems_fall_back_to_native() {
    let Some(eng) = engine() else { return };
    let before = eng.fallbacks();
    let (x, y, w) = problem(7, 150, 5, 8); // N=150 > 128
    let (_, p_e) = eng.ols_batch(&x, &y, &w, 1e-4).unwrap();
    assert!(eng.fallbacks() > before, "fallback not counted");
    // And the fallback result is the native result exactly.
    let native = NativeBackend::new();
    let (_, p_n) = native.ols_batch(&x, &y, &w, 1e-4).unwrap();
    assert!(p_e.max_abs_diff(&p_n) < 1e-12);
}

#[test]
fn ernest_model_parity_between_backends() {
    let Some(eng) = engine() else { return };
    let mut rng = Pcg::seed(8);
    let rows: Vec<Vec<f64>> = (0..30)
        .map(|_| vec![rng.range(2, 13) as f64, rng.range_f64(10.0, 30.0)])
        .collect();
    let y: Vec<f64> = rows
        .iter()
        .map(|r| 20.0 + 3.0 * r[1] / r[0] + 5.0 * r[0].log2() + 0.8 * r[0])
        .collect();
    let data = TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap();

    let mut e_pjrt = Ernest::new(eng);
    let mut e_native = Ernest::new(Arc::new(NativeBackend::new()));
    e_pjrt.fit(&data).unwrap();
    e_native.fit(&data).unwrap();
    for s in [2u32, 6, 12] {
        let q = [s as f64, 20.0];
        let a = e_pjrt.predict_one(&q).unwrap();
        let b = e_native.predict_one(&q).unwrap();
        assert!((a / b - 1.0).abs() < 0.05, "s={s}: pjrt {a} vs native {b}");
    }
}

#[test]
fn bom_model_parity_between_backends() {
    let Some(eng) = engine() else { return };
    let mut rng = Pcg::seed(9);
    let mut rows = Vec::new();
    let mut y = Vec::new();
    for i in 0..48 {
        let s = rng.range(2, 13) as f64;
        let (d, k) = if i % 2 == 0 {
            (20.0, 5.0)
        } else {
            (rng.range_f64(10.0, 30.0), rng.range(3, 10) as f64)
        };
        rows.push(vec![s, d, k]);
        y.push((1.0 / s + 0.02 * s) * (10.0 + 4.0 * d + 9.0 * k));
    }
    let data = TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap();

    let mut b_pjrt = Bom::new(eng);
    let mut b_native = Bom::new(Arc::new(NativeBackend::new()));
    b_pjrt.fit(&data).unwrap();
    b_native.fit(&data).unwrap();
    for s in [3u32, 8, 11] {
        let q = [s as f64, 20.0, 5.0];
        let a = b_pjrt.predict_one(&q).unwrap();
        let b = b_native.predict_one(&q).unwrap();
        assert!((a / b - 1.0).abs() < 0.08, "s={s}: pjrt {a} vs native {b}");
    }
}

#[test]
fn engine_survives_concurrent_callers() {
    let Some(eng) = engine() else { return };
    let handles: Vec<_> = (0..6)
        .map(|t| {
            let eng = eng.clone();
            std::thread::spawn(move || {
                for i in 0..5 {
                    let (x, y, w) = problem(100 + t * 10 + i, 24, 4, 8);
                    let (_, p) = eng.ols_batch(&x, &y, &w, 1e-4).unwrap();
                    assert_eq!(p.rows(), 8);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
