//! Integration: generate → train → configure → execute, end to end.
//!
//! The decisive check is the paper's §IV-B guarantee: across many
//! deadline-constrained configurations, the empirical deadline-hit rate
//! must reach the requested confidence.

use std::sync::Arc;

use c3o::cloud::{Catalog, CloudProvider};
use c3o::configurator::{configure, UserGoals};
use c3o::data::JobKind;
use c3o::runtime::NativeBackend;
use c3o::sim::{generate_job, Executor, GeneratorConfig, JobInput, WorkloadModel};
use c3o::util::prng::Pcg;

#[test]
fn deadline_hit_rate_reaches_confidence() {
    let catalog = Catalog::aws_like();
    let shared =
        generate_job(JobKind::Grep, &GeneratorConfig::default(), &catalog).unwrap();
    let provider = CloudProvider::new(Catalog::aws_like());
    let exec = Executor::new(&provider, WorkloadModel::default(), 0xE2E);
    let backend: Arc<dyn c3o::runtime::FitBackend> = Arc::new(NativeBackend::new());

    let mut rng = Pcg::seed(0xDEAD11);
    let mut hits = 0usize;
    let mut total = 0usize;
    let confidence = 0.90;
    for _ in 0..40 {
        let d = rng.range_f64(10.0, 20.0);
        let ratio = *rng.choose(&[0.001, 0.01, 0.1]);
        let input = JobInput::new(JobKind::Grep, d, vec![ratio]);
        // A deadline that is feasible but not trivial: interpolate between
        // the fastest and slowest catalog runtimes for this input.
        let model = WorkloadModel::default();
        let mt = catalog.get("m5.xlarge").unwrap();
        let t_fast = model.mean_runtime(mt, 12, &input);
        let t_slow = model.mean_runtime(mt, 2, &input);
        let deadline = t_fast + 0.5 * (t_slow - t_fast);

        let goals = UserGoals { deadline_s: Some(deadline), confidence };
        let choice = match configure(
            &catalog,
            &shared,
            Some("m5.xlarge"),
            &input,
            &goals,
            backend.clone(),
        ) {
            Ok(c) => c,
            Err(_) => continue, // infeasible at this confidence: skip
        };
        let report = exec
            .run(
                &c3o::cloud::ClusterConfig {
                    machine_type: choice.machine_type.clone(),
                    scale_out: choice.scale_out,
                },
                &input,
                Some(deadline),
            )
            .unwrap();
        total += 1;
        if report.deadline_met == Some(true) {
            hits += 1;
        }
    }
    assert!(total >= 25, "too many infeasible cases: {total}");
    let rate = hits as f64 / total as f64;
    assert!(
        rate >= confidence - 0.07, // finite-sample slack on 40 trials
        "deadline hit rate {rate:.2} < confidence {confidence}"
    );
    assert_eq!(provider.active_clusters(), 0, "leaked clusters");
}

#[test]
fn configurator_avoids_memory_cliff_in_practice() {
    // K-Means 20 GB on c5.xlarge: the simulator has a spill cliff below
    // ~6 nodes. The configurator must steer clear and the executed
    // runtime must be cliff-free.
    let catalog = Catalog::aws_like();
    let shared =
        generate_job(JobKind::KMeans, &GeneratorConfig::default(), &catalog).unwrap();
    let backend: Arc<dyn c3o::runtime::FitBackend> = Arc::new(NativeBackend::new());
    let input = JobInput::new(JobKind::KMeans, 20.0, vec![6.0, 0.001]);
    let goals = UserGoals { deadline_s: None, confidence: 0.95 };
    let choice = configure(
        &catalog,
        &shared,
        Some("c5.xlarge"),
        &input,
        &goals,
        backend,
    )
    .unwrap();
    // 20 GB * 1.25 / (0.55 * 8 GB) = 5.7 ⇒ s >= 6 is clean.
    assert!(choice.scale_out >= 6, "picked cliffed scale-out {}", choice.scale_out);
}

#[test]
fn predictions_track_executions_within_materials_error() {
    // Train on the shared corpus, execute fresh runs, and check the
    // predictor's MAPE against *live* executions (not just held-out data).
    let catalog = Catalog::aws_like();
    let shared = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog)
        .unwrap()
        .for_machine("m5.xlarge");
    let data = c3o::models::TrainData::from_dataset(&shared).unwrap();
    let backend: Arc<dyn c3o::runtime::FitBackend> = Arc::new(NativeBackend::new());
    let mut predictor = c3o::models::C3oPredictor::new(backend);
    predictor.fit(&data).unwrap();

    let provider = CloudProvider::new(Catalog::aws_like());
    let exec = Executor::new(&provider, WorkloadModel::default(), 77);
    let mut rng = Pcg::seed(0xACC);
    let mut errs = Vec::new();
    for _ in 0..30 {
        let s = rng.range(2, 13) as u32;
        let d = rng.range_f64(10.0, 20.0);
        let input = JobInput::new(JobKind::Sort, d, vec![]);
        let pred = predictor.predict_one(&[s as f64, d]).unwrap();
        let rep = exec
            .run(
                &c3o::cloud::ClusterConfig {
                    machine_type: "m5.xlarge".into(),
                    scale_out: s,
                },
                &input,
                None,
            )
            .unwrap();
        errs.push(((pred - rep.record.runtime_s) / rep.record.runtime_s).abs());
    }
    let mape = 100.0 * errs.iter().sum::<f64>() / errs.len() as f64;
    // Live single runs carry full run-to-run noise (the corpus stores
    // medians of five), so the bound is looser than Table II's.
    assert!(mape < 12.0, "live MAPE {mape:.2}%");
}
