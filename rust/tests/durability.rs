//! Durable hub storage (DESIGN.md §9), end to end:
//!
//! * every *acknowledged* submission survives a crash — WAL-only recovery,
//!   with and without a snapshot, with and without a torn trailing record,
//! * repository revisions are strictly monotone across restarts,
//! * a recovered hub predicts **bit-identically** to one that never
//!   restarted,
//! * rejected contributions leave WAL, revision and cache state untouched,
//! * the TCP server's graceful drain flushes and snapshots everything.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use c3o::api::service::PredictionService;
use c3o::cloud::Catalog;
use c3o::data::{Dataset, JobKind, RunRecord};
use c3o::hub::{HubClient, HubServer, HubState, Repository, ServerConfig, ValidationPolicy};
use c3o::runtime::NativeBackend;
use c3o::sim::{generate_job, GeneratorConfig, JobInput, WorkloadModel};
use c3o::storage::{DurableStore, FsyncPolicy, StorageConfig};
use c3o::util::prng::Pcg;

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("c3o_durability_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn honest_runs(n: usize, seed: u64) -> Dataset {
    let catalog = Catalog::aws_like();
    let model = WorkloadModel::default();
    let mt = catalog.get("m5.xlarge").unwrap();
    let mut rng = Pcg::seed(seed);
    let mut ds = Dataset::new(JobKind::Sort);
    for _ in 0..n {
        let s = rng.range(2, 13) as u32;
        let input = JobInput::new(JobKind::Sort, rng.range_f64(10.0, 20.0), vec![]);
        ds.push(model.observe(mt, s, &input, &mut rng)).unwrap();
    }
    ds
}

fn open(dir: &Path, fsync: FsyncPolicy) -> (Arc<DurableStore>, Vec<c3o::storage::RecoveredRepo>) {
    let (store, recovered) =
        DurableStore::open(dir, StorageConfig { fsync, snapshot_every: 0 }).unwrap();
    (Arc::new(store), recovered)
}

/// A durable hub with an empty Sort repository (bootstrap regime: the
/// §III-C-b retrain gate is not armed yet, so submits are cheap).
fn durable_hub(dir: &Path, fsync: FsyncPolicy) -> (HubState, Arc<DurableStore>) {
    let state = HubState::new();
    state.insert(Repository::new(JobKind::Sort, "sorting"));
    let (store, recovered) = open(dir, fsync);
    assert!(recovered.is_empty(), "fresh dir must recover nothing");
    state.set_storage(store.clone()).unwrap();
    (state, store)
}

#[test]
fn acknowledged_submits_survive_crash_without_any_snapshot() {
    let dir = fresh_dir("wal_only");
    let (state, store) = durable_hub(&dir, FsyncPolicy::Never);
    let policy = ValidationPolicy::default();

    let mut acknowledged: Vec<RunRecord> = Vec::new();
    for seed in 0..3u64 {
        let contrib = honest_runs(3, 100 + seed);
        let (verdict, revision) = state.submit(contrib.clone(), &policy).unwrap();
        assert!(verdict.accepted, "{}", verdict.reason);
        assert_eq!(revision, seed + 1);
        acknowledged.extend(contrib.records);
    }
    assert_eq!(store.stats().wal_appends, 3);

    // Crash: no sync, no snapshot, no graceful anything.
    drop(state);
    drop(store);

    let (_, recovered) = open(&dir, FsyncPolicy::Never);
    assert_eq!(recovered.len(), 1);
    let sort = &recovered[0];
    assert_eq!(sort.job, JobKind::Sort);
    assert_eq!(sort.revision, 3, "revision watermark survives the restart");
    assert_eq!(sort.replayed, 3);
    assert_eq!(
        sort.data.records, acknowledged,
        "every acknowledged contribution recovered, in commit order"
    );
    assert!(sort.description.is_none(), "no snapshot ran — no manifest metadata");

    // Revisions continue monotonically from the recovered watermark.
    let state = HubState::new();
    state.insert(Repository::new(JobKind::Sort, "sorting"));
    let (store, recovered) = open(&dir, FsyncPolicy::Never);
    for r in recovered {
        state.install_recovered(r);
    }
    state.set_storage(store).unwrap();
    let (verdict, revision) = state.submit(honest_runs(2, 999), &policy).unwrap();
    assert!(verdict.accepted, "{}", verdict.reason);
    assert_eq!(revision, 4, "post-recovery commits extend the revision line");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_trailing_record_is_truncated_acknowledged_survive() {
    let dir = fresh_dir("torn");
    let (state, store) = durable_hub(&dir, FsyncPolicy::Never);
    let policy = ValidationPolicy::default();
    for seed in 0..3u64 {
        let (verdict, _) = state.submit(honest_runs(3, 200 + seed), &policy).unwrap();
        assert!(verdict.accepted, "{}", verdict.reason);
    }
    drop(state);
    drop(store);

    let wal = dir.join("wal").join("sort.wal");
    let clean = std::fs::read(&wal).unwrap();

    // Kill -9 arrived mid-append: garbage tail after the acknowledged
    // records.
    let mut torn = clean.clone();
    torn.extend_from_slice(&[0xC3, 0x0C, 0xAF, 0xFE, 0x00, 0x01, 0x02]);
    std::fs::write(&wal, &torn).unwrap();
    let (store, recovered) = open(&dir, FsyncPolicy::Never);
    assert_eq!(store.torn_tails(), 1);
    assert_eq!(recovered[0].revision, 3);
    assert_eq!(recovered[0].data.len(), 9, "acknowledged records all survive");
    assert_eq!(
        std::fs::metadata(&wal).unwrap().len(),
        clean.len() as u64,
        "the torn trailing record is truncated on open"
    );
    drop(store);

    // Crash half-way through the *last valid* record instead: exactly the
    // unacknowledged half-write disappears, the prefix stays.
    let mut cut = clean.clone();
    cut.truncate(clean.len() - 5);
    std::fs::write(&wal, &cut).unwrap();
    let (store, recovered) = open(&dir, FsyncPolicy::Never);
    assert_eq!(store.torn_tails(), 1);
    assert_eq!(recovered[0].revision, 2);
    assert_eq!(recovered[0].data.len(), 6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn snapshot_compacts_wal_and_restores_metadata() {
    let dir = fresh_dir("snapshot");
    let state = HubState::new();
    let mut repo = Repository::new(JobKind::Sort, "standard Spark sort implementation");
    repo.maintainer_machine = Some("m5.xlarge".into());
    state.insert(repo);
    let (store, _) = open(&dir, FsyncPolicy::Interval);
    state.set_storage(store.clone()).unwrap();
    let policy = ValidationPolicy::default();

    for seed in 0..2u64 {
        let (verdict, _) = state.submit(honest_runs(3, 300 + seed), &policy).unwrap();
        assert!(verdict.accepted, "{}", verdict.reason);
    }
    let wal = dir.join("wal").join("sort.wal");
    assert!(std::fs::metadata(&wal).unwrap().len() > 0);

    let seq = state.snapshot_to(&store).unwrap();
    assert_eq!(seq, 1);
    assert_eq!(
        std::fs::metadata(&wal).unwrap().len(),
        0,
        "snapshot compacts the covered WAL records away"
    );

    // One more acknowledged submit after the snapshot, then crash.
    let (verdict, revision) = state.submit(honest_runs(3, 310), &policy).unwrap();
    assert!(verdict.accepted, "{}", verdict.reason);
    assert_eq!(revision, 3);
    drop(state);
    drop(store);

    let (_, recovered) = open(&dir, FsyncPolicy::Interval);
    let sort = recovered.iter().find(|r| r.job == JobKind::Sort).unwrap();
    assert_eq!(sort.revision, 3, "snapshot watermark + WAL tail");
    assert_eq!(sort.replayed, 1, "only the post-snapshot record replays");
    assert_eq!(sort.data.len(), 9);
    assert_eq!(
        sort.description.as_deref(),
        Some("standard Spark sort implementation"),
        "manifest restores the description"
    );
    assert_eq!(
        sort.maintainer_machine.as_deref(),
        Some("m5.xlarge"),
        "manifest restores the maintainer designation"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn rejected_contribution_touches_neither_wal_nor_state() {
    let dir = fresh_dir("rejected");
    let catalog = Catalog::aws_like();
    let state = HubState::new();
    let mut repo = Repository::new(JobKind::Sort, "sorting");
    repo.maintainer_machine = Some("m5.xlarge".into());
    repo.data = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
    state.insert(repo);
    let (store, _) = open(&dir, FsyncPolicy::Always);
    // Baseline snapshot first: set_storage refuses to attach over a
    // pre-populated repository the store does not cover.
    state.snapshot_to(&store).unwrap();
    state.set_storage(store.clone()).unwrap();
    let policy = ValidationPolicy::default();

    let wal = dir.join("wal").join("sort.wal");
    let len_before = std::fs::metadata(&wal).unwrap().len();

    // Fabricated runtimes: the §III-C-b gate bounces them.
    let mut poison = Dataset::new(JobKind::Sort);
    let mut rng = Pcg::seed(7);
    for _ in 0..25 {
        poison
            .push(RunRecord {
                machine_type: "m5.xlarge".into(),
                scale_out: rng.range(2, 13) as u32,
                data_size_gb: rng.range_f64(10.0, 20.0),
                context: vec![],
                runtime_s: 1e7,
            })
            .unwrap();
    }
    let (verdict, revision) = state.submit(poison, &policy).unwrap();
    assert!(!verdict.accepted);
    assert_eq!(revision, 0, "rejection does not bump the revision");
    assert_eq!(state.counters(), (0, 1), "rejection is counted");
    assert_eq!(
        std::fs::metadata(&wal).unwrap().len(),
        len_before,
        "rejection must not append to the WAL"
    );
    assert_eq!(store.stats().wal_appends, 0);

    // A replayed (duplicate) contribution is rejected and equally silent.
    let contrib = honest_runs(4, 42);
    let (verdict, _) = state.submit(contrib.clone(), &policy).unwrap();
    assert!(verdict.accepted, "{}", verdict.reason);
    let after_accept = std::fs::metadata(&wal).unwrap().len();
    assert!(after_accept > len_before);

    let (verdict, revision) = state.submit(contrib, &policy).unwrap();
    assert!(!verdict.accepted, "replay must be rejected");
    assert!(verdict.reason.contains("duplicate"), "{}", verdict.reason);
    assert_eq!(revision, 1, "revision unchanged by the replay");
    assert_eq!(state.counters(), (1, 2));
    assert_eq!(std::fs::metadata(&wal).unwrap().len(), after_accept);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn set_storage_refuses_uncovered_prepopulated_repo() {
    // Attaching a fresh store to a repo that already holds records would
    // lose them at the next recovery (the store rebuilds repos only from
    // snapshot + WAL) — so it must fail up front, and succeed after a
    // baseline snapshot.
    let dir = fresh_dir("uncovered");
    let catalog = Catalog::aws_like();
    let state = HubState::new();
    let mut repo = Repository::new(JobKind::Sort, "sorting");
    repo.data = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
    state.insert(repo);
    let (store, _) = open(&dir, FsyncPolicy::Never);
    let err = state.set_storage(store.clone()).unwrap_err();
    assert!(format!("{err:#}").contains("does not cover"), "{err:#}");
    assert!(state.storage().is_none(), "refused attach leaves no storage");

    state.snapshot_to(&store).unwrap();
    state.set_storage(store).unwrap();
    assert!(state.storage().is_some());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn recovered_hub_predicts_bit_identically() {
    let dir = fresh_dir("parity");
    let catalog = Catalog::aws_like();
    let backend = Arc::new(NativeBackend::new());
    let state = Arc::new(HubState::new());
    let mut repo = Repository::new(JobKind::Sort, "standard Spark sort implementation");
    repo.maintainer_machine = Some("m5.xlarge".into());
    repo.data = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
    state.insert(repo);
    let (store, _) = open(&dir, FsyncPolicy::Interval);
    // Baseline snapshot: the seeded corpus is captured before the first
    // WAL append builds on it (exactly what `c3o serve --data-dir` does).
    state.snapshot_to(&store).unwrap();
    state.set_storage(store.clone()).unwrap();

    let live = PredictionService::new(
        state.clone(),
        catalog.clone(),
        ValidationPolicy::default(),
        backend.clone(),
    );
    for seed in [51u64, 52u64] {
        let tsv = honest_runs(4, seed).to_table().unwrap().to_text().unwrap();
        let out = live.submit_tsv(JobKind::Sort, &tsv).unwrap();
        assert!(out.accepted, "{}", out.reason);
    }
    let rows: Vec<Vec<f64>> = (2..=12).map(|s| vec![s as f64, 15.0]).collect();
    let before = live.predict_batch(JobKind::Sort, None, &rows).unwrap();

    // Release the live hub's store first — the data dir is single-writer
    // locked — then restart purely from disk: the WAL tail replays onto
    // the baseline snapshot; no graceful shutdown happened.
    drop(store);
    drop(state.detach_storage());
    let (_, recovered) = open(&dir, FsyncPolicy::Interval);
    let state2 = Arc::new(HubState::new());
    for r in recovered {
        state2.install_recovered(r);
    }
    assert_eq!(
        state2.revision(JobKind::Sort),
        state.revision(JobKind::Sort),
        "revisions match across the restart"
    );
    assert_eq!(
        state2.get(JobKind::Sort).unwrap().data.records,
        state.get(JobKind::Sort).unwrap().data.records,
        "recovered dataset is value-identical to the live one"
    );
    let recovered_svc = PredictionService::new(
        state2,
        catalog,
        ValidationPolicy::default(),
        backend,
    );
    let after = recovered_svc.predict_batch(JobKind::Sort, None, &rows).unwrap();
    assert_eq!(after.machine_type, before.machine_type);
    assert_eq!(after.model, before.model, "same model wins selection");
    for (a, b) in before.runtimes.iter().zip(&after.runtimes) {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "recovered hub must predict bit-identically"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn server_graceful_drain_flushes_and_snapshots() {
    let dir = fresh_dir("drain");
    let catalog = Catalog::aws_like();
    let state = Arc::new(HubState::new());
    let mut repo = Repository::new(JobKind::Sort, "sorting");
    repo.maintainer_machine = Some("m5.xlarge".into());
    state.insert(repo);
    let (store, _) = open(&dir, FsyncPolicy::Interval);
    state.snapshot_to(&store).unwrap();
    state.set_storage(store.clone()).unwrap();
    let service = Arc::new(PredictionService::new(
        state,
        catalog,
        ValidationPolicy::default(),
        Arc::new(NativeBackend::new()),
    ));
    let server = HubServer::start_with(
        "127.0.0.1:0",
        service,
        ServerConfig { workers: 2, max_conns: 16, ..ServerConfig::default() },
    )
    .unwrap();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();
    let verdict = client.submit_runs(&honest_runs(5, 77)).unwrap();
    assert!(verdict.accepted, "{}", verdict.reason);
    let stats = client.stats().unwrap();
    assert!(stats.durable, "stats must report the durable store");
    assert_eq!(stats.wal_appends, 1);
    drop(client);
    server.shutdown();
    // The server (service → state → store) is gone; drop the test's own
    // handle too so the data dir's single-writer lock is released.
    drop(store);

    // The drain wrote a final compacted snapshot: recovery needs no WAL.
    assert_eq!(
        std::fs::metadata(dir.join("wal").join("sort.wal")).unwrap().len(),
        0,
        "graceful drain compacts the WAL into the final snapshot"
    );
    let (_, recovered) = open(&dir, FsyncPolicy::Interval);
    let sort = recovered.iter().find(|r| r.job == JobKind::Sort).unwrap();
    assert_eq!(sort.revision, 1);
    assert_eq!(sort.data.len(), 5);
    assert_eq!(sort.replayed, 0, "everything came from the final snapshot");
    assert_eq!(sort.maintainer_machine.as_deref(), Some("m5.xlarge"));
    std::fs::remove_dir_all(&dir).ok();
}
