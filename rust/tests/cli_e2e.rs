//! Integration: the `c3o` CLI binary end to end, plus failure injection on
//! the artifact loading path.

use std::process::Command;

fn c3o() -> Command {
    Command::new(env!("CARGO_BIN_EXE_c3o"))
}

#[test]
fn generate_then_configure_from_disk() {
    let dir = std::env::temp_dir().join(format!("c3o_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // generate
    let out = c3o()
        .args(["generate", "--out", dir.to_str().unwrap(), "--seed", "77"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("930"), "{stdout}");
    for job in ["sort", "grep", "sgd", "kmeans", "pagerank"] {
        assert!(dir.join(format!("{job}.tsv")).exists(), "{job}.tsv missing");
    }

    // configure against the generated corpus
    let out = c3o()
        .args([
            "configure",
            "--job",
            "kmeans",
            "--size",
            "15",
            "--ctx",
            "7,0.001",
            "--deadline",
            "900",
            "--confidence",
            "0.95",
            "--data",
            dir.to_str().unwrap(),
            "--backend",
            "native",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("machine type : m5.xlarge"), "{stdout}");
    assert!(stdout.contains("scale-out"), "{stdout}");
    assert!(stdout.contains("runtime/cost pairs"), "{stdout}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn configure_via_hub_matches_local_mode() {
    use std::io::{BufRead, BufReader};
    use std::process::Stdio;

    let dir = std::env::temp_dir().join(format!("c3o_hub_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Shared corpus on disk (deterministic seed).
    let out = c3o()
        .args(["generate", "--out", dir.to_str().unwrap(), "--seed", "909"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // Serve it on an ephemeral port; the listening line reports the addr.
    let mut serve = c3o()
        .args([
            "serve", "--addr", "127.0.0.1:0", "--data", dir.to_str().unwrap(),
            "--backend", "native",
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let mut first_line = String::new();
    BufReader::new(serve.stdout.as_mut().unwrap())
        .read_line(&mut first_line)
        .unwrap();
    let addr = first_line.trim().rsplit(' ').next().unwrap().to_string();
    assert!(addr.contains(':'), "no addr in: {first_line}");

    let configure_args = |mode: &[&str]| {
        let mut a = vec![
            "configure", "--job", "sort", "--size", "15", "--deadline", "900",
            "--confidence", "0.95", "--backend", "native",
        ];
        a.extend_from_slice(mode);
        a.iter().map(|s| s.to_string()).collect::<Vec<_>>()
    };
    let local = c3o()
        .args(configure_args(&["--data", dir.to_str().unwrap()]))
        .output()
        .unwrap();
    assert!(local.status.success(), "{}", String::from_utf8_lossy(&local.stderr));
    let hub = c3o().args(configure_args(&["--hub", &addr])).output().unwrap();
    assert!(hub.status.success(), "{}", String::from_utf8_lossy(&hub.stderr));

    // Same chosen machine type and scale-out, local vs hub-delegated.
    let pick = |stdout: &[u8]| -> (String, String) {
        let text = String::from_utf8_lossy(stdout).to_string();
        let grab = |tag: &str| {
            text.lines()
                .find(|l| l.contains(tag))
                .unwrap_or_else(|| panic!("missing `{tag}` in: {text}"))
                .to_string()
        };
        (grab("machine type"), grab("scale-out"))
    };
    assert_eq!(pick(&local.stdout), pick(&hub.stdout));

    // Closing stdin shuts the hub down.
    drop(serve.stdin.take());
    let status = serve.wait().unwrap();
    assert!(status.success());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn configure_with_impossible_deadline_fails_cleanly() {
    let out = c3o()
        .args([
            "configure", "--job", "sort", "--size", "20", "--deadline", "1",
            "--backend", "native",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("no scale-out"), "{stderr}");
}

#[test]
fn unknown_subcommand_exits_2() {
    let out = c3o().args(["frobnicate"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn unknown_job_is_an_error() {
    let out = c3o()
        .args(["configure", "--job", "mapreduce", "--size", "10", "--backend", "native"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown job"));
}

// --- Failure injection on the artifact path -------------------------------

#[test]
fn engine_rejects_corrupt_manifest() {
    use c3o::runtime::Engine;
    let dir = std::env::temp_dir().join(format!("c3o_art_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // Wrong shape constants.
    std::fs::write(dir.join("MANIFEST.tsv"), "# N=4\tF=8\tB=128\tQ=64\nname\tsha\tshapes\n")
        .unwrap();
    let err = match Engine::load(&dir) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("corrupt manifest accepted"),
    };
    assert!(err.contains("N=4"), "{err}");

    // Manifest lists a module that does not exist.
    std::fs::write(
        dir.join("MANIFEST.tsv"),
        "# N=128\tF=8\tB=128\tQ=64\nghost_module\tdeadbeef\tf32[1]\n",
    )
    .unwrap();
    let err = match Engine::load(&dir) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("ghost manifest accepted"),
    };
    assert!(err.contains("ghost_module"), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn engine_rejects_unparseable_hlo() {
    use c3o::runtime::Engine;
    let dir = std::env::temp_dir().join(format!("c3o_badhlo_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("MANIFEST.tsv"), "# N=128\tF=8\tB=128\tQ=64\n").unwrap();
    for m in ["ols_batch", "nnls_batch", "predict_grid"] {
        std::fs::write(dir.join(format!("{m}.hlo.txt")), "this is not HLO").unwrap();
    }
    assert!(Engine::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
