//! Integration: leader + follower hubs over real TCP (DESIGN.md §11).
//!
//! Covers the tentpole end-to-end scenarios: a leader and two follower
//! hubs converge to bit-identical `predict_batch` answers after submits
//! land on the leader only; `submit_runs` on a follower is refused with a
//! typed `not_leader` error naming the leader; a follower killed without
//! any graceful drain (kill -9 equivalent) reopens its own durable state
//! and resumes tailing from its watermark with no gaps and no
//! double-applies; and a cold follower behind the leader's compaction
//! horizon bootstraps from the snapshot image.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use c3o::api::service::PredictionService;
use c3o::cloud::Catalog;
use c3o::data::{Dataset, JobKind};
use c3o::hub::{HubClient, HubServer, HubState, Repository, ValidationPolicy};
use c3o::replication::{install_snapshot, sync_once, FollowerConfig, Tailer};
use c3o::runtime::NativeBackend;
use c3o::sim::{JobInput, WorkloadModel};
use c3o::storage::{DurableStore, FsyncPolicy, StorageConfig};
use c3o::util::prng::Pcg;

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("c3o_repl_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn open_store(dir: &Path) -> (Arc<DurableStore>, Vec<c3o::storage::RecoveredRepo>) {
    let config = StorageConfig { fsync: FsyncPolicy::Never, snapshot_every: 0 };
    let (store, recovered) = DurableStore::open(dir, config).unwrap();
    (Arc::new(store), recovered)
}

/// Hub state the CLI way: empty registered repositories (data arrives via
/// submits or replication — revision 0 always means an empty corpus, so
/// every record the leader holds is reachable through WAL revisions).
fn empty_state() -> Arc<HubState> {
    let state = Arc::new(HubState::new());
    for job in [JobKind::Sort, JobKind::Grep] {
        let mut repo = Repository::new(job, &format!("spark {job}"));
        repo.maintainer_machine = Some("m5.xlarge".to_string());
        state.insert(repo);
    }
    state
}

fn service_on(state: Arc<HubState>) -> Arc<PredictionService> {
    // Replication semantics are under test, not the §III-C-b gate: with
    // `min_existing: usize::MAX` every honest submit bootstrap-accepts
    // deterministically, so acceptance never depends on corpus shape.
    let policy = ValidationPolicy { min_existing: usize::MAX, ..Default::default() };
    Arc::new(PredictionService::new(
        state,
        Catalog::aws_like(),
        policy,
        Arc::new(NativeBackend::new()),
    ))
}

/// A durable leader hub serving on an ephemeral port.
fn start_leader(dir: &Path) -> HubServer {
    let state = empty_state();
    let (store, recovered) = open_store(dir);
    for r in recovered {
        state.install_recovered(r);
    }
    state.set_storage(store).unwrap();
    HubServer::start("127.0.0.1:0", service_on(state)).unwrap()
}

/// A durable follower hub: recovers its own state, marks itself read-only,
/// and tails `leader` in the background exactly as `c3o serve --follow`.
fn start_follower(dir: &Path, leader: &str) -> HubServer {
    let state = empty_state();
    let (store, recovered) = open_store(dir);
    for r in recovered {
        state.install_recovered(r);
    }
    state.set_storage(store).unwrap();
    let service = service_on(state);
    service.set_follower_of(leader);
    let mut server = HubServer::start("127.0.0.1:0", service).unwrap();
    let tailer = Tailer::start(server.service().clone(), FollowerConfig::new(leader));
    server.attach_tailer(tailer);
    server
}

fn honest_runs(job: JobKind, n: usize, seed: u64) -> Dataset {
    let catalog = Catalog::aws_like();
    let model = WorkloadModel::default();
    let mt = catalog.get("m5.xlarge").unwrap();
    let mut rng = Pcg::seed(seed);
    let mut ds = Dataset::new(job);
    for _ in 0..n {
        let s = rng.range(2, 13) as u32;
        let (d, ctx) = match job {
            JobKind::Sort => (rng.range_f64(10.0, 20.0), vec![]),
            JobKind::KMeans => (rng.range_f64(10.0, 20.0), vec![5.0, 0.001]),
            _ => (rng.range_f64(10.0, 20.0), vec![0.01]),
        };
        let input = JobInput::new(job, d, ctx);
        ds.push(model.observe(mt, s, &input, &mut rng)).unwrap();
    }
    ds
}

fn wait_until(timeout: Duration, mut ready: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    loop {
        if ready() {
            return true;
        }
        if start.elapsed() >= timeout {
            return false;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Canonical corpus bytes, for bit-identity comparisons.
fn corpus_tsv(client: &mut HubClient, job: JobKind) -> (u64, String) {
    let repo = client.get_repo(job).unwrap();
    (repo.revision, repo.data.to_table().unwrap().to_text().unwrap())
}

#[test]
fn leader_and_two_followers_converge_bit_identically() {
    let ldir = fresh_dir("leader");
    let adir = fresh_dir("follower_a");
    let bdir = fresh_dir("follower_b");
    let leader = start_leader(&ldir);
    let leader_addr = leader.addr.to_string();

    // Submits land on the leader only.
    let mut lc = HubClient::connect(&leader_addr).unwrap();
    for (n, seed) in [(30, 1), (20, 2)] {
        let out = lc.submit_runs(&honest_runs(JobKind::Sort, n, seed)).unwrap();
        assert!(out.accepted, "{}", out.reason);
    }
    assert!(lc.submit_runs(&honest_runs(JobKind::Grep, 30, 3)).unwrap().accepted);

    let fa = start_follower(&adir, &leader_addr);
    let fb = start_follower(&bdir, &leader_addr);
    let mut ca = HubClient::connect(&fa.addr.to_string()).unwrap();
    let mut cb = HubClient::connect(&fb.addr.to_string()).unwrap();

    // Both followers converge to the leader's per-repo watermarks ...
    let lstats = lc.stats().unwrap();
    assert_eq!(
        lstats.per_repo.iter().find(|r| r.job == JobKind::Sort).unwrap().revision,
        2
    );
    let converged = wait_until(Duration::from_secs(30), || {
        [&mut ca, &mut cb]
            .into_iter()
            .all(|c| c.stats().unwrap().per_repo == lstats.per_repo)
    });
    assert!(converged, "followers did not reach the leader's watermarks");

    // ... with byte-identical corpora ...
    for job in [JobKind::Sort, JobKind::Grep] {
        let want = corpus_tsv(&mut lc, job);
        assert_eq!(corpus_tsv(&mut ca, job), want, "follower A diverged on {job}");
        assert_eq!(corpus_tsv(&mut cb, job), want, "follower B diverged on {job}");
    }

    // ... and bit-identical predict_batch answers (each hub fits its own
    // model on its replicated revision — determinism does the rest).
    let rows: Vec<Vec<f64>> = (2..=12).map(|s| vec![s as f64, 15.0]).collect();
    let want = lc.predict_batch(JobKind::Sort, None, &rows).unwrap();
    for (name, c) in [("A", &mut ca), ("B", &mut cb)] {
        let got = c.predict_batch(JobKind::Sort, None, &rows).unwrap();
        assert_eq!(got.model, want.model, "follower {name} chose another model");
        for (g, w) in got.runtimes.iter().zip(want.runtimes.iter()) {
            assert_eq!(g.to_bits(), w.to_bits(), "follower {name} prediction differs");
        }
    }

    // Writes on a follower are refused with a typed not_leader error
    // naming the leader.
    let err = ca.submit_runs(&honest_runs(JobKind::Sort, 4, 9)).unwrap_err().to_string();
    assert!(err.contains("not_leader"), "{err}");
    assert!(err.contains(&leader_addr), "error must name the leader: {err}");
    // The refused follower still serves reads.
    ca.stats().unwrap();

    // A later submit on the leader reaches both followers too.
    assert!(lc.submit_runs(&honest_runs(JobKind::Sort, 6, 4)).unwrap().accepted);
    let caught_up = wait_until(Duration::from_secs(30), || {
        [&mut ca, &mut cb]
            .into_iter()
            .all(|c| c.get_repo(JobKind::Sort).unwrap().revision == 3)
    });
    assert!(caught_up, "followers missed the post-convergence submit");
    let want = corpus_tsv(&mut lc, JobKind::Sort);
    assert_eq!(corpus_tsv(&mut ca, JobKind::Sort), want);
    assert_eq!(corpus_tsv(&mut cb, JobKind::Sort), want);

    fa.shutdown();
    fb.shutdown();
    leader.shutdown();
    for dir in [ldir, adir, bdir] {
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn follower_killed_mid_tail_resumes_from_its_watermark() {
    let ldir = fresh_dir("kill_leader");
    let fdir = fresh_dir("kill_follower");
    let leader = start_leader(&ldir);
    let leader_addr = leader.addr.to_string();
    let mut lc = HubClient::connect(&leader_addr).unwrap();
    for (n, seed) in [(10, 21), (8, 22), (6, 23)] {
        assert!(lc.submit_runs(&honest_runs(JobKind::Sort, n, seed)).unwrap().accepted);
    }

    // First follower incarnation: apply only part of the log (a tailer
    // interrupted mid-page), then die with no drain, no sync, no snapshot
    // — the kill -9 equivalent for in-process state.
    {
        let state = empty_state();
        let (store, recovered) = open_store(&fdir);
        assert!(recovered.is_empty());
        state.set_storage(store).unwrap();
        let service = service_on(state);
        let mut repl = HubClient::connect(&leader_addr).unwrap();
        let page = repl.repl_fetch(JobKind::Sort, 0, 2).unwrap();
        assert_eq!(page.records.len(), 2, "mid-tail: two of three revisions applied");
        for rec in &page.records {
            service.apply_replicated(JobKind::Sort, rec.revision, &rec.data_tsv).unwrap();
        }
        drop(service.state().detach_storage());
        // Everything (state, service, store Arc) drops here unsynced.
    }

    // Reopen the same data dir: recovery replays the follower's own WAL.
    let state = empty_state();
    let (store, recovered) = open_store(&fdir);
    let sort = recovered.into_iter().find(|r| r.job == JobKind::Sort).unwrap();
    assert_eq!(sort.revision, 2, "watermark survived the crash");
    assert_eq!(sort.replayed, 2, "both applied records replay from the WAL");
    let expected_records = sort.data.len();
    state.install_recovered(sort);
    state.set_storage(store).unwrap();
    assert_eq!(state.get(JobKind::Sort).unwrap().data.len(), expected_records);
    let service = service_on(state);
    service.set_follower_of(leader_addr.as_str());

    // Re-applying an already-applied revision is refused: no double-apply
    // after the restart.
    let mut repl = HubClient::connect(&leader_addr).unwrap();
    let replay = repl.repl_fetch(JobKind::Sort, 0, 1).unwrap();
    let err = service
        .apply_replicated(JobKind::Sort, replay.records[0].revision, &replay.records[0].data_tsv)
        .unwrap_err()
        .to_string();
    assert!(err.contains("replication gap"), "{err}");

    // Resuming from the watermark closes the gap left by the crash.
    let applied = sync_once(&service, &mut repl, 64).unwrap();
    assert_eq!(applied, 1, "exactly the missing revision is fetched");
    assert_eq!(service.state().revision(JobKind::Sort), Some(3));
    let follower_tsv = {
        let repo = service.state().get(JobKind::Sort).unwrap();
        repo.data.to_table().unwrap().to_text().unwrap()
    };
    assert_eq!(corpus_tsv(&mut lc, JobKind::Sort), (3, follower_tsv));

    drop(service.state().detach_storage());
    leader.shutdown();
    for dir in [ldir, fdir] {
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn cold_follower_behind_the_compaction_horizon_bootstraps_from_snapshot() {
    let ldir = fresh_dir("snap_leader");
    let leader = start_leader(&ldir);
    let leader_addr = leader.addr.to_string();
    let mut lc = HubClient::connect(&leader_addr).unwrap();
    for (n, seed) in [(10, 31), (8, 32)] {
        assert!(lc.submit_runs(&honest_runs(JobKind::Sort, n, seed)).unwrap().accepted);
    }
    // Compact the leader's WAL: revisions 1-2 now exist only in the
    // snapshot, so a cold follower cannot tail from revision 0.
    let store = leader.state().storage().unwrap();
    leader.state().snapshot_to(&store).unwrap();

    let state = empty_state();
    let service = service_on(state);
    service.set_follower_of(leader_addr.as_str());
    let mut repl = HubClient::connect(&leader_addr).unwrap();
    let hs = repl.repl_subscribe(JobKind::Sort, 0).unwrap();
    assert_eq!(hs.leader_revision, 2);
    assert!(hs.compacted, "cold start behind the horizon must be flagged");

    // sync_once detects the horizon itself and falls back to the
    // snapshot image; install_snapshot is also callable directly.
    let applied = sync_once(&service, &mut repl, 64).unwrap();
    assert_eq!(applied, 0, "bootstrap installs the image; no WAL records to apply");
    assert_eq!(service.state().revision(JobKind::Sort), Some(2));
    assert_eq!(install_snapshot(&service, &mut repl).unwrap(), 0, "already current");
    let follower_tsv = {
        let repo = service.state().get(JobKind::Sort).unwrap();
        repo.data.to_table().unwrap().to_text().unwrap()
    };
    assert_eq!(corpus_tsv(&mut lc, JobKind::Sort), (2, follower_tsv));

    // Post-bootstrap submits replicate incrementally through the WAL.
    assert!(lc.submit_runs(&honest_runs(JobKind::Sort, 6, 33)).unwrap().accepted);
    assert_eq!(sync_once(&service, &mut repl, 64).unwrap(), 1);
    assert_eq!(service.state().revision(JobKind::Sort), Some(3));
    assert_eq!(
        corpus_tsv(&mut lc, JobKind::Sort).1,
        service
            .state()
            .get(JobKind::Sort)
            .unwrap()
            .data
            .to_table()
            .unwrap()
            .to_text()
            .unwrap()
    );

    leader.shutdown();
    std::fs::remove_dir_all(&ldir).ok();
}
