//! Wire protocol v1 conformance over live TCP: structured error paths,
//! client-side envelope checks, the fitted-model cache through the public
//! API, and hub/local configurator parity.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use c3o::api::service::PredictionService;
use c3o::cloud::Catalog;
use c3o::configurator::{
    configure, fit_prepared_with, select_scale_out, ConfigChoice, MIN_RUNS_PER_TYPE, TypeOutcome,
    UserGoals,
};
use c3o::cv::FitEngine;
use c3o::data::JobKind;
use c3o::hub::{HubClient, HubServer, HubState, Repository, ValidationPolicy};
use c3o::runtime::NativeBackend;
use c3o::sim::{generate_job, GeneratorConfig, JobInput};

fn start_hub() -> HubServer {
    let state = Arc::new(HubState::new());
    let catalog = Catalog::aws_like();
    for job in [JobKind::Sort, JobKind::Grep] {
        let mut repo = Repository::new(job, &format!("spark {job}"));
        repo.maintainer_machine = Some("m5.xlarge".to_string());
        repo.data = generate_job(job, &GeneratorConfig::default(), &catalog).unwrap();
        state.insert(repo);
    }
    let service = Arc::new(PredictionService::new(
        state,
        catalog,
        ValidationPolicy::default(),
        Arc::new(NativeBackend::new()),
    ));
    HubServer::start("127.0.0.1:0", service).unwrap()
}

/// Send raw frames over one connection, collecting one reply line each.
fn roundtrip_raw(addr: &str, frames: &[&str]) -> Vec<String> {
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = Vec::new();
    for frame in frames {
        stream.write_all(frame.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "connection dropped on frame: {frame}");
        out.push(line);
    }
    out
}

#[test]
fn every_protocol_error_is_structured_and_survivable() {
    let server = start_hub();
    let addr = server.addr.to_string();

    // All on ONE connection: a structured error must never cost the
    // connection.
    let replies = roundtrip_raw(
        &addr,
        &[
            // 1. malformed JSON
            "{{{ definitely not json",
            // 2. not an object
            "[1,2,3]",
            // 3. missing version
            r#"{"id":1,"op":"stats"}"#,
            // 4. wrong version
            r#"{"v":99,"id":2,"op":"stats"}"#,
            // 5. missing id
            r#"{"v":1,"op":"stats"}"#,
            // 6. unknown op
            r#"{"v":1,"id":3,"op":"frobnicate"}"#,
            // 7. missing op field
            r#"{"v":1,"id":4}"#,
            // 8. missing required op argument
            r#"{"v":1,"id":5,"op":"get_repo"}"#,
            // 9. bad argument value
            r#"{"v":1,"id":6,"op":"get_repo","job":"mapreduce"}"#,
            // 10. missing repository
            r#"{"v":1,"id":7,"op":"get_repo","job":"pagerank"}"#,
            // ... and the connection still answers real requests.
            r#"{"v":1,"id":8,"op":"stats"}"#,
        ],
    );
    let expect = [
        ("bad_request", "\"id\":0"),
        ("bad_request", "\"id\":0"),
        ("version_mismatch", "\"id\":1"),
        ("version_mismatch", "\"id\":2"),
        ("missing_field", "\"id\":0"),
        ("unknown_op", "\"id\":3"),
        ("missing_field", "\"id\":4"),
        ("missing_field", "\"id\":5"),
        ("invalid_data", "\"id\":6"),
        ("not_found", "\"id\":7"),
    ];
    for (i, (code, id)) in expect.iter().enumerate() {
        assert!(replies[i].contains("\"ok\":false"), "frame {i}: {}", replies[i]);
        assert!(replies[i].contains(code), "frame {i}: want {code}: {}", replies[i]);
        assert!(replies[i].contains(id), "frame {i}: want {id}: {}", replies[i]);
    }
    assert!(replies[10].contains("\"ok\":true"), "{}", replies[10]);
    server.shutdown();
}

#[test]
fn client_rejects_mismatched_response_id() {
    // A fake hub that answers with the wrong correlation id.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let mut writer = stream;
        writer
            .write_all(b"{\"v\":1,\"id\":999,\"ok\":true,\"payload\":{}}\n")
            .unwrap();
        writer.flush().unwrap();
    });

    let mut client = HubClient::connect(&addr).unwrap();
    let err = client.stats().unwrap_err();
    assert!(err.to_string().contains("id mismatch"), "{err:#}");
    fake.join().unwrap();
}

#[test]
fn client_rejects_mismatched_response_version() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let mut writer = stream;
        writer
            .write_all(b"{\"v\":7,\"id\":1,\"ok\":true,\"payload\":{}}\n")
            .unwrap();
        writer.flush().unwrap();
    });

    let mut client = HubClient::connect(&addr).unwrap();
    let err = client.stats().unwrap_err();
    assert!(err.to_string().contains("version mismatch"), "{err:#}");
    fake.join().unwrap();
}

#[test]
fn predict_batch_warm_cache_zero_refits_over_the_wire() {
    let server = start_hub();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();

    // Cold: the first predict fits.
    let p = client.predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
    assert!(!p.cached);
    assert!(p.runtime_s.is_finite());
    assert_eq!(p.machine_type, "m5.xlarge", "maintainer designation wins");
    let s = client.stats().unwrap();
    assert_eq!(s.fits, 1);

    // Warm: a batch over the whole scale-out range, zero refits.
    let rows: Vec<Vec<f64>> = (2..=12).map(|so| vec![so as f64, 15.0]).collect();
    let b = client.predict_batch(JobKind::Sort, None, &rows).unwrap();
    assert!(b.cached);
    assert_eq!(b.runtimes.len(), rows.len());
    assert_eq!(b.model, p.model, "same fitted model as the single predict");
    let s = client.stats().unwrap();
    assert_eq!(s.fits, 1, "warm predict_batch must not refit");
    assert!(s.cache_hits >= 1);
    assert_eq!(s.cache_entries, 1);

    // An accepted contribution invalidates ONLY the touched job.
    client.predict(JobKind::Grep, None, &[4.0, 15.0, 0.01]).unwrap();
    let s = client.stats().unwrap();
    assert_eq!(s.fits, 2);

    let contrib = {
        use c3o::sim::WorkloadModel;
        use c3o::util::prng::Pcg;
        let catalog = Catalog::aws_like();
        let model = WorkloadModel::default();
        let mt = catalog.get("m5.xlarge").unwrap();
        let mut rng = Pcg::seed(77);
        let mut ds = c3o::data::Dataset::new(JobKind::Sort);
        for _ in 0..8 {
            let so = rng.range(2, 13) as u32;
            let input = JobInput::new(JobKind::Sort, rng.range_f64(10.0, 20.0), vec![]);
            ds.push(model.observe(mt, so, &input, &mut rng)).unwrap();
        }
        ds
    };
    let verdict = client.submit_runs(&contrib).unwrap();
    assert!(verdict.accepted, "{}", verdict.reason);
    assert_eq!(verdict.revision, 1);

    // Grep still cached; sort refits on its new revision.
    let g = client.predict(JobKind::Grep, None, &[4.0, 15.0, 0.01]).unwrap();
    assert!(g.cached);
    let s = client.stats().unwrap();
    assert_eq!(s.fits, 2, "grep unaffected by the sort contribution");
    let p2 = client.predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
    assert!(!p2.cached, "sort cache entry invalidated by accepted submit");
    let s = client.stats().unwrap();
    assert_eq!(s.fits, 3);
    server.shutdown();
}

#[test]
fn hub_configure_matches_local_configure() {
    let server = start_hub();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();
    let catalog = Catalog::aws_like();
    // The exact corpus the hub serves (same generator, same seed).
    let shared = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
    let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };

    let local = configure(
        &catalog,
        &shared,
        Some("m5.xlarge"),
        &JobInput::new(JobKind::Sort, 15.0, vec![]),
        &goals,
        Arc::new(NativeBackend::new()),
    )
    .unwrap();
    let remote = client
        .configure(JobKind::Sort, 15.0, vec![], &goals, None)
        .unwrap();

    assert_eq!(remote.machine_type, local.machine_type);
    assert_eq!(remote.scale_out, local.scale_out);
    assert!((remote.predicted_runtime_s - local.predicted_runtime_s).abs() < 1e-9);
    assert!((remote.runtime_ucb_s - local.runtime_ucb_s).abs() < 1e-9);
    assert!((remote.est_cost_usd - local.est_cost_usd).abs() < 1e-9);
    assert_eq!(remote.options.len(), local.options.len());
    for (r, l) in remote.options.iter().zip(&local.options) {
        assert_eq!(r.scale_out, l.scale_out);
        assert_eq!(r.bottleneck, l.bottleneck);
        assert_eq!(r.admissible, l.admissible);
    }
    server.shutdown();
}

/// The documented cross-type reduction over an exhaustive per-type
/// `select_scale_out` loop — the independent reference the grid search
/// must match bit-for-bit.
fn exhaustive_search(
    catalog: &Catalog,
    shared: &c3o::data::Dataset,
    input: &JobInput,
    goals: &UserGoals,
) -> ConfigChoice {
    let view = shared.feature_view();
    let mut best: Option<ConfigChoice> = None;
    for mt in catalog.types() {
        if view.rows(&mt.name) < MIN_RUNS_PER_TYPE {
            continue;
        }
        let (predictor, report) = fit_prepared_with(
            &view,
            &mt.name,
            Arc::new(NativeBackend::new()),
            &FitEngine::serial(),
        )
        .unwrap();
        let Ok(choice) = select_scale_out(
            catalog,
            &mt.name,
            &predictor,
            input,
            goals,
            report.chosen_score.resid_mean,
            report.chosen_score.resid_std,
        ) else {
            continue;
        };
        let bottleneck = |c: &ConfigChoice| {
            c.options.iter().find(|o| o.scale_out == c.scale_out).unwrap().bottleneck
        };
        let better = match &best {
            None => true,
            Some(b) => match (bottleneck(&choice), bottleneck(b)) {
                (false, true) => true,
                (true, false) => false,
                _ => match choice.est_cost_usd.total_cmp(&b.est_cost_usd) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Greater => false,
                    std::cmp::Ordering::Equal => choice.machine_type < b.machine_type,
                },
            },
        };
        if better {
            best = Some(choice);
        }
    }
    best.expect("at least one admissible type")
}

#[test]
fn configure_search_over_hub_matches_exhaustive_loop_with_zero_warm_refits() {
    let server = start_hub();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();
    let catalog = Catalog::aws_like();
    let shared = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
    let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };
    let input = JobInput::new(JobKind::Sort, 15.0, vec![]);

    let remote = client.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap();
    let local = exhaustive_search(&catalog, &shared, &input, &goals);

    // Bit-identical winner, grid search vs exhaustive per-type loop.
    assert_eq!(remote.choice.machine_type, local.machine_type);
    assert_eq!(remote.choice.scale_out, local.scale_out);
    assert_eq!(remote.choice.predicted_runtime_s.to_bits(), local.predicted_runtime_s.to_bits());
    assert_eq!(remote.choice.runtime_ucb_s.to_bits(), local.runtime_ucb_s.to_bits());
    assert_eq!(remote.choice.est_cost_usd.to_bits(), local.est_cost_usd.to_bits());

    // Every catalog type is accounted for: 2 evaluated (the corpus covers
    // m5.xlarge and c5.xlarge), the rest reported insufficient_data.
    assert_eq!(remote.types.len(), catalog.types().len());
    let evaluated = remote
        .types
        .iter()
        .filter(|t| matches!(t.outcome, TypeOutcome::Evaluated { .. }))
        .count();
    let insufficient = remote
        .types
        .iter()
        .filter(|t| matches!(t.outcome, TypeOutcome::InsufficientData { .. }))
        .count();
    assert_eq!(evaluated, 2);
    assert_eq!(insufficient, catalog.types().len() - 2);

    // Frontier: cost-ranked, admissible under the deadline.
    assert!(!remote.frontier.is_empty());
    for w in remote.frontier.windows(2) {
        assert!(w[0].cost_usd <= w[1].cost_usd);
    }
    for f in &remote.frontier {
        assert!(f.runtime_ucb_s <= 900.0);
    }

    // The first grid search paid one cold fit per evaluated type; a warm
    // repeat answers the whole catalog with ZERO refits (the service's
    // fit counters are authoritative).
    let s = client.stats().unwrap();
    assert_eq!(s.fits as usize, evaluated, "one cold fit per evaluated type");
    let again = client.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap();
    assert_eq!(again.choice.machine_type, remote.choice.machine_type);
    assert_eq!(again.choice.scale_out, remote.choice.scale_out);
    let s2 = client.stats().unwrap();
    assert_eq!(s2.fits, s.fits, "warm full-grid search must perform zero refits");
    assert!(s2.cache_hits >= s.cache_hits + evaluated as u64);
    server.shutdown();
}

#[test]
fn configure_search_error_paths_are_structured() {
    let server = start_hub();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();

    // Unknown repository -> not_found.
    let err = client
        .configure_search(JobKind::PageRank, 0.25, vec![0.1, 0.001], &UserGoals::default())
        .unwrap_err();
    assert!(err.to_string().contains("not_found"), "{err:#}");

    // Deadline-impossible grid -> invalid_data, connection survives.
    let goals = UserGoals { deadline_s: Some(1.0), confidence: 0.95 };
    let err = client.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap_err();
    assert!(err.to_string().contains("invalid_data"), "{err:#}");
    assert!(err.to_string().contains("none admissible"), "{err:#}");

    // Out-of-range confidence -> invalid_data (over the raw frame, since
    // the typed client cannot send one).
    let replies = roundtrip_raw(
        &server.addr.to_string(),
        &[r#"{"v":1,"id":1,"op":"configure_search","job":"sort","data_size_gb":1,"confidence":9}"#],
    );
    assert!(replies[0].contains("\"ok\":false"), "{}", replies[0]);
    assert!(replies[0].contains("invalid_data"), "{}", replies[0]);

    // And the hub still serves after all of the above.
    client.stats().unwrap();
    server.shutdown();
}

#[test]
fn configure_error_paths_are_structured() {
    let server = start_hub();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();

    // Impossible deadline -> invalid_data with the configurator's message.
    let goals = UserGoals { deadline_s: Some(1.0), confidence: 0.95 };
    let err = client
        .configure(JobKind::Sort, 15.0, vec![], &goals, None)
        .unwrap_err();
    assert!(err.to_string().contains("no scale-out"), "{err:#}");

    // Unknown repository -> not_found.
    let goals = UserGoals::default();
    let err = client
        .configure(JobKind::PageRank, 0.25, vec![0.1, 0.001], &goals, None)
        .unwrap_err();
    assert!(err.to_string().contains("not_found"), "{err:#}");
    server.shutdown();
}
