//! Integration: the full collaborative loop over a live TCP hub.
//!
//! Covers the Fig. 4 workflow (browse → fetch → contribute) plus the
//! §III-C-b validation gate under honest, corrupted and malicious
//! contributions, and concurrent client safety.

use std::sync::Arc;

use c3o::cloud::Catalog;
use c3o::data::{Dataset, JobKind, RunRecord};
use c3o::hub::{HubClient, HubServer, HubState, Repository, ValidationPolicy};
use c3o::sim::{generate_job, GeneratorConfig, JobInput, WorkloadModel};
use c3o::util::prng::Pcg;

fn start_hub_with_data() -> HubServer {
    let state = Arc::new(HubState::new());
    let catalog = Catalog::aws_like();
    for job in [JobKind::Sort, JobKind::Grep] {
        let mut repo = Repository::new(job, &format!("spark {job}"));
        repo.maintainer_machine = Some("m5.xlarge".to_string());
        repo.data = generate_job(job, &GeneratorConfig::default(), &catalog).unwrap();
        state.insert(repo);
    }
    // Empty repo to exercise the bootstrap path.
    state.insert(Repository::new(JobKind::KMeans, "spark kmeans"));
    HubServer::start("127.0.0.1:0", state, catalog, ValidationPolicy::default()).unwrap()
}

fn honest_runs(job: JobKind, n: usize, seed: u64) -> Dataset {
    let catalog = Catalog::aws_like();
    let model = WorkloadModel::default();
    let mt = catalog.get("m5.xlarge").unwrap();
    let mut rng = Pcg::seed(seed);
    let mut ds = Dataset::new(job);
    for _ in 0..n {
        let s = rng.range(2, 13) as u32;
        let (d, ctx) = match job {
            JobKind::Sort => (rng.range_f64(10.0, 20.0), vec![]),
            JobKind::KMeans => (rng.range_f64(10.0, 20.0), vec![5.0, 0.001]),
            _ => (rng.range_f64(10.0, 20.0), vec![0.01]),
        };
        let input = JobInput::new(job, d, ctx);
        ds.push(model.observe(mt, s, &input, &mut rng)).unwrap();
    }
    ds
}

#[test]
fn browse_fetch_contribute_roundtrip() {
    let server = start_hub_with_data();
    let addr = server.addr.to_string();
    let mut client = HubClient::connect(&addr).unwrap();

    // Step 1: browse.
    let repos = client.list_repos().unwrap();
    assert_eq!(repos.len(), 3);
    let sort = repos.iter().find(|r| r.job == JobKind::Sort).unwrap();
    assert_eq!(sort.records, 126);
    assert_eq!(sort.maintainer_machine.as_deref(), Some("m5.xlarge"));

    // Step 2: fetch code + runtime data.
    let fetched = client.get_repo(JobKind::Sort).unwrap();
    assert_eq!(fetched.data.len(), 126);

    // Step 6: contribute honest new runs.
    let contrib = honest_runs(JobKind::Sort, 8, 42);
    let (accepted, reason) = client.submit_runs(&contrib).unwrap();
    assert!(accepted, "{reason}");

    // The shared dataset grew.
    let after = client.get_repo(JobKind::Sort).unwrap();
    assert_eq!(after.data.len(), 126 + 8);

    let (acc, rej, repos) = client.stats().unwrap();
    assert_eq!((acc, rej, repos), (1, 0, 3));
    server.shutdown();
}

#[test]
fn malicious_contribution_rejected_and_quarantined() {
    let server = start_hub_with_data();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();

    let mut poison = Dataset::new(JobKind::Sort);
    let mut rng = Pcg::seed(7);
    for _ in 0..30 {
        poison
            .push(RunRecord {
                machine_type: "m5.xlarge".into(),
                scale_out: rng.range(2, 13) as u32,
                data_size_gb: rng.range_f64(10.0, 20.0),
                context: vec![],
                runtime_s: 1e7, // fabricated
            })
            .unwrap();
    }
    let (accepted, reason) = client.submit_runs(&poison).unwrap();
    assert!(!accepted, "poison accepted: {reason}");

    // Repo unchanged; rejection counted.
    let after = client.get_repo(JobKind::Sort).unwrap();
    assert_eq!(after.data.len(), 126);
    let (acc, rej, _) = client.stats().unwrap();
    assert_eq!((acc, rej), (0, 1));
    server.shutdown();
}

#[test]
fn wire_level_garbage_is_survivable() {
    use std::io::{BufRead, BufReader, Write};
    let server = start_hub_with_data();
    let mut raw = std::net::TcpStream::connect(server.addr).unwrap();
    raw.write_all(b"this is not json\n").unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");

    // Unknown op.
    raw.write_all(b"{\"op\":\"frobnicate\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("unknown op"), "{line}");

    // The connection (and server) still works afterwards.
    raw.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
    server.shutdown();
}

#[test]
fn bootstrap_repo_accepts_first_data_then_validates() {
    let server = start_hub_with_data();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();

    // KMeans repo is empty: bootstrap accepts honest data.
    let first = honest_runs(JobKind::KMeans, 8, 1);
    let (accepted, reason) = client.submit_runs(&first).unwrap();
    assert!(accepted, "{reason}");

    // Grow past the bootstrap threshold.
    let more = honest_runs(JobKind::KMeans, 10, 2);
    let (accepted, _) = client.submit_runs(&more).unwrap();
    assert!(accepted);

    // Now the gate is armed: poison must bounce.
    let mut poison = honest_runs(JobKind::KMeans, 20, 3);
    for r in &mut poison.records {
        r.runtime_s *= 500.0;
    }
    let (accepted, reason) = client.submit_runs(&poison).unwrap();
    assert!(!accepted, "poison accepted after bootstrap: {reason}");
    server.shutdown();
}

#[test]
fn concurrent_clients_consistent_state() {
    let server = start_hub_with_data();
    let addr = server.addr.to_string();
    let mut handles = Vec::new();
    for t in 0..6 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = HubClient::connect(&addr).unwrap();
            for i in 0..5 {
                let contrib = honest_runs(JobKind::Sort, 3, 1000 + t * 100 + i);
                let _ = c.submit_runs(&contrib).unwrap();
                let _ = c.list_repos().unwrap();
                let _ = c.get_repo(JobKind::Grep).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut c = HubClient::connect(&addr).unwrap();
    let (acc, rej, _) = c.stats().unwrap();
    assert_eq!(acc + rej, 30, "every submission got a verdict");
    let repo = c.get_repo(JobKind::Sort).unwrap();
    assert_eq!(repo.data.len(), 126 + (acc as usize) * 3);
    server.shutdown();
}

#[test]
fn get_missing_repo_is_clean_error() {
    let server = start_hub_with_data();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();
    let err = client.get_repo(JobKind::PageRank).unwrap_err();
    assert!(err.to_string().contains("no repository"), "{err:#}");
    server.shutdown();
}
