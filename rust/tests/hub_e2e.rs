//! Integration: the full collaborative loop over a live TCP hub.
//!
//! Covers the Fig. 4 workflow (browse → fetch → contribute) plus the
//! §III-C-b validation gate under honest, corrupted and malicious
//! contributions, concurrent client safety, and shutdown quiescence —
//! all over wire protocol v1.

use std::sync::Arc;

use c3o::api::service::PredictionService;
use c3o::cloud::Catalog;
use c3o::data::{Dataset, JobKind, RunRecord};
use c3o::hub::{HubClient, HubServer, HubState, Repository, ServerConfig, ValidationPolicy};
use c3o::runtime::NativeBackend;
use c3o::sim::{generate_job, GeneratorConfig, JobInput, WorkloadModel};
use c3o::util::prng::Pcg;

fn start_hub_with_data() -> HubServer {
    let state = Arc::new(HubState::new());
    let catalog = Catalog::aws_like();
    for job in [JobKind::Sort, JobKind::Grep] {
        let mut repo = Repository::new(job, &format!("spark {job}"));
        repo.maintainer_machine = Some("m5.xlarge".to_string());
        repo.data = generate_job(job, &GeneratorConfig::default(), &catalog).unwrap();
        state.insert(repo);
    }
    // Empty repo to exercise the bootstrap path.
    state.insert(Repository::new(JobKind::KMeans, "spark kmeans"));
    let service = Arc::new(PredictionService::new(
        state,
        catalog,
        ValidationPolicy::default(),
        Arc::new(NativeBackend::new()),
    ));
    HubServer::start("127.0.0.1:0", service).unwrap()
}

fn honest_runs(job: JobKind, n: usize, seed: u64) -> Dataset {
    let catalog = Catalog::aws_like();
    let model = WorkloadModel::default();
    let mt = catalog.get("m5.xlarge").unwrap();
    let mut rng = Pcg::seed(seed);
    let mut ds = Dataset::new(job);
    for _ in 0..n {
        let s = rng.range(2, 13) as u32;
        let (d, ctx) = match job {
            JobKind::Sort => (rng.range_f64(10.0, 20.0), vec![]),
            JobKind::KMeans => (rng.range_f64(10.0, 20.0), vec![5.0, 0.001]),
            _ => (rng.range_f64(10.0, 20.0), vec![0.01]),
        };
        let input = JobInput::new(job, d, ctx);
        ds.push(model.observe(mt, s, &input, &mut rng)).unwrap();
    }
    ds
}

#[test]
fn browse_fetch_contribute_roundtrip() {
    let server = start_hub_with_data();
    let addr = server.addr.to_string();
    let mut client = HubClient::connect(&addr).unwrap();

    // Step 1: browse.
    let repos = client.list_repos().unwrap();
    assert_eq!(repos.len(), 3);
    let sort = repos.iter().find(|r| r.job == JobKind::Sort).unwrap();
    assert_eq!(sort.records, 126);
    assert_eq!(sort.maintainer_machine.as_deref(), Some("m5.xlarge"));
    assert_eq!(sort.revision, 0);

    // Step 2: fetch code + runtime data.
    let fetched = client.get_repo(JobKind::Sort).unwrap();
    assert_eq!(fetched.data.len(), 126);
    assert_eq!(fetched.revision, 0);

    // Step 6: contribute honest new runs.
    let contrib = honest_runs(JobKind::Sort, 8, 42);
    let verdict = client.submit_runs(&contrib).unwrap();
    assert!(verdict.accepted, "{}", verdict.reason);
    assert_eq!(verdict.revision, 1, "accepted contribution bumps the revision");

    // The shared dataset grew.
    let after = client.get_repo(JobKind::Sort).unwrap();
    assert_eq!(after.data.len(), 126 + 8);
    assert_eq!(after.revision, 1);

    let s = client.stats().unwrap();
    assert_eq!((s.accepted, s.rejected, s.repos), (1, 0, 3));
    server.shutdown();
}

#[test]
fn malicious_contribution_rejected_and_quarantined() {
    let server = start_hub_with_data();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();

    let mut poison = Dataset::new(JobKind::Sort);
    let mut rng = Pcg::seed(7);
    for _ in 0..30 {
        poison
            .push(RunRecord {
                machine_type: "m5.xlarge".into(),
                scale_out: rng.range(2, 13) as u32,
                data_size_gb: rng.range_f64(10.0, 20.0),
                context: vec![],
                runtime_s: 1e7, // fabricated
            })
            .unwrap();
    }
    let verdict = client.submit_runs(&poison).unwrap();
    assert!(!verdict.accepted, "poison accepted: {}", verdict.reason);
    assert_eq!(verdict.revision, 0, "rejected contribution keeps the revision");

    // Repo unchanged; rejection counted.
    let after = client.get_repo(JobKind::Sort).unwrap();
    assert_eq!(after.data.len(), 126);
    let s = client.stats().unwrap();
    assert_eq!((s.accepted, s.rejected), (0, 1));
    server.shutdown();
}

#[test]
fn wire_level_garbage_is_survivable() {
    use std::io::{BufRead, BufReader, Write};
    let server = start_hub_with_data();
    let mut raw = std::net::TcpStream::connect(server.addr).unwrap();
    raw.write_all(b"this is not json\n").unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(line.contains("bad_request"), "{line}");

    // Unknown op.
    raw.write_all(b"{\"v\":1,\"id\":1,\"op\":\"frobnicate\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("unknown_op"), "{line}");
    assert!(line.contains("unknown op"), "{line}");

    // The connection (and server) still works afterwards.
    raw.write_all(b"{\"v\":1,\"id\":2,\"op\":\"stats\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":true"), "{line}");
    server.shutdown();
}

#[test]
fn bootstrap_repo_accepts_first_data_then_validates() {
    let server = start_hub_with_data();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();

    // KMeans repo is empty: bootstrap accepts honest data.
    let first = honest_runs(JobKind::KMeans, 8, 1);
    let verdict = client.submit_runs(&first).unwrap();
    assert!(verdict.accepted, "{}", verdict.reason);

    // Grow past the bootstrap threshold.
    let more = honest_runs(JobKind::KMeans, 10, 2);
    assert!(client.submit_runs(&more).unwrap().accepted);

    // Now the gate is armed: poison must bounce.
    let mut poison = honest_runs(JobKind::KMeans, 20, 3);
    for r in &mut poison.records {
        r.runtime_s *= 500.0;
    }
    let verdict = client.submit_runs(&poison).unwrap();
    assert!(!verdict.accepted, "poison accepted after bootstrap: {}", verdict.reason);
    server.shutdown();
}

#[test]
fn concurrent_clients_consistent_state() {
    let server = start_hub_with_data();
    let addr = server.addr.to_string();
    let mut handles = Vec::new();
    for t in 0..6 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = HubClient::connect(&addr).unwrap();
            for i in 0..5 {
                let contrib = honest_runs(JobKind::Sort, 3, 1000 + t * 100 + i);
                let _ = c.submit_runs(&contrib).unwrap();
                let _ = c.list_repos().unwrap();
                let _ = c.get_repo(JobKind::Grep).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let mut c = HubClient::connect(&addr).unwrap();
    let s = c.stats().unwrap();
    assert_eq!(s.accepted + s.rejected, 30, "every submission got a verdict");
    let repo = c.get_repo(JobKind::Sort).unwrap();
    assert_eq!(repo.data.len(), 126 + (s.accepted as usize) * 3);
    assert_eq!(repo.revision, s.accepted, "one revision bump per accepted submit");
    server.shutdown();
}

/// Worker-pool stress: concurrent clients mix `predict_batch` and
/// `submit_runs` across *different* jobs (per-job submit locks commit in
/// parallel). Afterwards: no lost updates (every accepted submit landed
/// exactly one revision and all its records), revisions are monotone per
/// client, and the stats counters add up to the submission count.
#[test]
fn stress_mixed_predicts_and_submits_across_jobs() {
    let state = Arc::new(HubState::new());
    let catalog = Catalog::aws_like();
    for job in [JobKind::Sort, JobKind::Grep] {
        let mut repo = Repository::new(job, &format!("spark {job}"));
        repo.maintainer_machine = Some("m5.xlarge".to_string());
        repo.data = generate_job(job, &GeneratorConfig::default(), &catalog).unwrap();
        state.insert(repo);
    }
    let service = Arc::new(PredictionService::new(
        state,
        catalog,
        ValidationPolicy::default(),
        Arc::new(NativeBackend::new()),
    ));
    let server = HubServer::start_with(
        "127.0.0.1:0",
        service,
        ServerConfig { workers: 12, max_conns: 64, ..ServerConfig::default() },
    )
    .unwrap();
    let addr = server.addr.to_string();

    let mut c0 = HubClient::connect(&addr).unwrap();
    let initial_sort = c0.get_repo(JobKind::Sort).unwrap().data.len();
    let initial_grep = c0.get_repo(JobKind::Grep).unwrap().data.len();

    const ROUNDS: usize = 3;
    const RECORDS_PER_SUBMIT: usize = 3;
    let mut submitters = Vec::new();
    for t in 0..4usize {
        let addr = addr.clone();
        submitters.push(std::thread::spawn(move || {
            let job = if t % 2 == 0 { JobKind::Sort } else { JobKind::Grep };
            let mut c = HubClient::connect(&addr).unwrap();
            let mut outcomes = Vec::new();
            for i in 0..ROUNDS {
                let seed = 7000 + (t * 100 + i) as u64;
                let contrib = honest_runs(job, RECORDS_PER_SUBMIT, seed);
                let v = c.submit_runs(&contrib).unwrap();
                outcomes.push((job, v.accepted, v.revision));
            }
            outcomes
        }));
    }
    let mut predictors = Vec::new();
    for t in 0..4usize {
        let addr = addr.clone();
        predictors.push(std::thread::spawn(move || {
            let mut c = HubClient::connect(&addr).unwrap();
            for i in 0..8usize {
                let job = if (t + i) % 2 == 0 { JobKind::Sort } else { JobKind::Grep };
                let rows: Vec<Vec<f64>> = (2..=9u32)
                    .map(|s| {
                        let mut r = vec![s as f64, 15.0];
                        if job == JobKind::Grep {
                            r.push(0.01);
                        }
                        r
                    })
                    .collect();
                let b = c.predict_batch(job, None, &rows).unwrap();
                assert_eq!(b.runtimes.len(), rows.len());
                assert!(b.runtimes.iter().all(|rt| rt.is_finite() && *rt > 0.0));
            }
        }));
    }

    let mut all = Vec::new();
    for h in submitters {
        let outcomes = h.join().unwrap();
        // Revisions one client observes for its job never go backwards.
        for w in outcomes.windows(2) {
            assert!(w[1].2 >= w[0].2, "revision went backwards: {w:?}");
        }
        all.extend(outcomes);
    }
    for h in predictors {
        h.join().unwrap();
    }

    for (job, initial) in [(JobKind::Sort, initial_sort), (JobKind::Grep, initial_grep)] {
        let mut accepted_revs: Vec<u64> = all
            .iter()
            .filter(|(j, acc, _)| *j == job && *acc)
            .map(|&(_, _, rev)| rev)
            .collect();
        accepted_revs.sort_unstable();
        let expect: Vec<u64> = (1..=accepted_revs.len() as u64).collect();
        assert_eq!(
            accepted_revs, expect,
            "{job}: each accepted submit commits exactly one revision"
        );
        let repo = c0.get_repo(job).unwrap();
        assert_eq!(repo.revision, accepted_revs.len() as u64);
        assert_eq!(
            repo.data.len(),
            initial + accepted_revs.len() * RECORDS_PER_SUBMIT,
            "{job}: accepted records must all land (no lost updates)"
        );
    }

    let s = c0.stats().unwrap();
    let accepted_total = all.iter().filter(|(_, acc, _)| *acc).count() as u64;
    assert_eq!(
        s.accepted + s.rejected,
        (4 * ROUNDS) as u64,
        "every submission got a verdict"
    );
    assert_eq!(s.accepted, accepted_total);
    server.shutdown();
}

#[test]
fn catalog_search_over_live_hub() {
    use c3o::configurator::{TypeOutcome, UserGoals};
    let server = start_hub_with_data();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();
    let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };

    let search = client.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap();
    // The winner is a real admissible configuration...
    assert!(search.choice.runtime_ucb_s <= 900.0);
    assert!(search.choice.est_cost_usd > 0.0);
    // ...every catalog type is reported (evaluated or insufficient_data),
    // and the frontier is cost-ranked.
    assert_eq!(search.types.len(), client.catalog().unwrap().types.len());
    let insufficient = search
        .types
        .iter()
        .any(|t| matches!(t.outcome, TypeOutcome::InsufficientData { .. }));
    assert!(insufficient, "types below the data floor must be reported");
    for w in search.frontier.windows(2) {
        assert!(w[0].cost_usd <= w[1].cost_usd);
    }

    // A contribution to the job invalidates the grid's models: the next
    // search refits, revision-correctly, instead of serving stale models.
    let fits_before = client.stats().unwrap().fits;
    let verdict = client.submit_runs(&honest_runs(JobKind::Sort, 8, 99)).unwrap();
    assert!(verdict.accepted, "{}", verdict.reason);
    let after = client.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap();
    assert!(client.stats().unwrap().fits > fits_before, "stale grid must refit");
    assert!(after.choice.runtime_ucb_s <= 900.0);

    // The empty bootstrap repo (kmeans) is a typed `unavailable`, not a
    // hang or a dropped connection.
    let e = client.configure_search(JobKind::KMeans, 15.0, vec![5.0, 0.001], &goals).unwrap_err();
    assert!(e.to_string().contains("unavailable"), "{e:#}");
    // The connection survives the error.
    client.stats().unwrap();
    server.shutdown();
}

#[test]
fn get_missing_repo_is_clean_error() {
    let server = start_hub_with_data();
    let mut client = HubClient::connect(&server.addr.to_string()).unwrap();
    let err = client.get_repo(JobKind::PageRank).unwrap_err();
    assert!(err.to_string().contains("no repository"), "{err:#}");
    assert!(err.to_string().contains("not_found"), "{err:#}");
    server.shutdown();
}

#[test]
fn connection_flood_is_refused_with_structured_unavailable() {
    use std::io::{BufRead, BufReader};
    let state = Arc::new(HubState::new());
    state.insert(Repository::new(JobKind::Sort, "spark sort"));
    let service = Arc::new(PredictionService::new(
        state,
        Catalog::aws_like(),
        ValidationPolicy::default(),
        Arc::new(NativeBackend::new()),
    ));
    let server = HubServer::start_with(
        "127.0.0.1:0",
        service,
        ServerConfig {
            workers: 1,
            // `max_conns` bounds *open* connections at accept time (the
            // reactor has no per-connection worker to queue for; an idle
            // socket costs one slot regardless of worker load).
            max_conns: 2,
            idle_timeout: std::time::Duration::from_secs(300),
            ..ServerConfig::default()
        },
    )
    .unwrap();

    // Fill both slots: a served client and a raw idle socket.
    let mut a = HubClient::connect(&server.addr.to_string()).unwrap();
    a.stats().unwrap();
    let b = std::net::TcpStream::connect(server.addr).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(80));

    // The flood overflow gets a structured v1 error frame, not a hangup.
    let c = std::net::TcpStream::connect(server.addr).unwrap();
    c.set_read_timeout(Some(std::time::Duration::from_secs(10))).unwrap();
    let mut line = String::new();
    BufReader::new(c).read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "{line}");
    assert!(line.contains("unavailable"), "{line}");
    assert!(line.contains("connection capacity"), "{line}");

    // The served connection keeps working through the flood...
    a.stats().unwrap();

    // ...and hanging up frees the slot for a fresh connection (the
    // reactor notices the hangup on its next tick).
    drop(b);
    let mut freed = None;
    for _ in 0..100 {
        std::thread::sleep(std::time::Duration::from_millis(20));
        let mut cand = HubClient::connect(&server.addr.to_string()).unwrap();
        if let Ok(s) = cand.stats() {
            assert_eq!(s.repos, 1);
            freed = Some(cand);
            break;
        }
    }
    assert!(freed.is_some(), "freed connection slot was never accepted");
    server.shutdown();
}

#[test]
fn idle_connection_is_reaped_unconditionally() {
    let state = Arc::new(HubState::new());
    state.insert(Repository::new(JobKind::Sort, "spark sort"));
    let service = Arc::new(PredictionService::new(
        state,
        Catalog::aws_like(),
        ValidationPolicy::default(),
        Arc::new(NativeBackend::new()),
    ));
    let server = HubServer::start_with(
        "127.0.0.1:0",
        service,
        ServerConfig {
            workers: 2,
            max_conns: 8,
            idle_timeout: std::time::Duration::from_millis(200),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr.to_string();

    // A connection idle past the deadline is closed even on an otherwise
    // empty hub — no queue-pressure precondition. (The blocking transport
    // reaped idle connections only while others queued for a worker; the
    // reactor reaps on the idle clock alone, so fd accounting stays
    // predictable and abandoned peers are freed promptly.)
    let mut a = HubClient::connect(&addr).unwrap();
    a.stats().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(700));
    let err = a.stats().unwrap_err();
    assert!(err.to_string().contains("closed"), "{err:#}");

    // Fresh connections are unaffected.
    let mut b = HubClient::connect(&addr).unwrap();
    assert_eq!(b.stats().unwrap().repos, 1);
    server.shutdown();
}

#[test]
fn pipelined_requests_complete_out_of_order_and_resolve_by_id() {
    use c3o::hub::PipelinedClient;
    let server = start_hub_with_data();
    let addr = server.addr.to_string();

    // Warm the Sort model — and grab reference predictions — through a
    // plain roundtrip client.
    let mut reference = HubClient::connect(&addr).unwrap();
    let rows: Vec<Vec<f64>> = (2..=6u32).map(|s| vec![s as f64, 15.0]).collect();
    let expect = reference.predict_batch(JobKind::Sort, None, &rows).unwrap();
    assert!(reference.stats().unwrap().fits >= 1);

    let mut p = PipelinedClient::connect(&addr).unwrap();
    // A cold Grep fit first (expensive: CV model selection over the
    // repo)...
    let cold = p.send_predict(JobKind::Grep, None, &[4.0, 15.0, 0.01]).unwrap();
    // ...then warm Sort hits queued behind it on the same connection.
    let warm: Vec<u64> =
        rows.iter().map(|r| p.send_predict(JobKind::Sort, None, r).unwrap()).collect();
    assert_eq!(p.in_flight(), rows.len() + 1);

    // The warm replies overtake the cold fit: waiting them out succeeds
    // while the cold reply has not arrived (`has_reply` never touches
    // the socket, so observing `false` after the warm waits proves true
    // server-side reordering, not client-side shuffling).
    for (i, id) in warm.iter().enumerate() {
        let pred = p.wait_predict(*id).unwrap();
        assert_eq!(pred.runtime_s.to_bits(), expect.runtimes[i].to_bits(), "row {i}");
        assert_eq!(pred.machine_type, expect.machine_type);
    }
    assert!(
        !p.has_reply(cold),
        "cold Grep fit finished before {} warm Sort hits — reordering unobservable",
        rows.len()
    );

    // The cold reply still resolves, correctly correlated.
    let coldp = p.wait_predict(cold).unwrap();
    assert!(!coldp.cached, "first Grep predict must be a cold fit");
    assert!(coldp.runtime_s.is_finite() && coldp.runtime_s > 0.0);
    assert_eq!(p.in_flight(), 0);
    server.shutdown();
}

#[test]
fn coalesced_predicts_match_individual_predicts_bit_for_bit() {
    let state = Arc::new(HubState::new());
    let catalog = Catalog::aws_like();
    let mut repo = Repository::new(JobKind::Sort, "spark sort");
    repo.maintainer_machine = Some("m5.xlarge".to_string());
    repo.data = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog).unwrap();
    state.insert(repo);
    let service = Arc::new(PredictionService::new(
        state,
        catalog,
        ValidationPolicy::default(),
        Arc::new(NativeBackend::new()),
    ));
    let server = HubServer::start_with(
        "127.0.0.1:0",
        service,
        ServerConfig {
            workers: 8,
            coalesce_window: std::time::Duration::from_millis(150),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr.to_string();

    // Reference rows via `predict_batch`, which bypasses the coalescer
    // but shares the same fitted-model path (and pays the one cold fit).
    let rows: Vec<Vec<f64>> = (2..=9u32).map(|s| vec![s as f64, 15.0]).collect();
    let mut c0 = HubClient::connect(&addr).unwrap();
    let expect = c0.predict_batch(JobKind::Sort, None, &rows).unwrap();

    // Barrier-released concurrent single-row predicts land inside one
    // coalescing window and are answered by one batched prediction.
    let barrier = Arc::new(std::sync::Barrier::new(rows.len()));
    let mut handles = Vec::new();
    for (i, row) in rows.iter().enumerate() {
        let addr = addr.clone();
        let row = row.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = HubClient::connect(&addr).unwrap();
            barrier.wait();
            (i, c.predict(JobKind::Sort, None, &row).unwrap())
        }));
    }
    for h in handles {
        let (i, pred) = h.join().unwrap();
        assert_eq!(
            pred.runtime_s.to_bits(),
            expect.runtimes[i].to_bits(),
            "row {i}: coalesced predict must be bit-identical to the individual path"
        );
        assert_eq!(pred.machine_type, expect.machine_type);
        assert_eq!(pred.model, expect.model);
    }

    let s = c0.stats().unwrap();
    assert!(s.coalesced_predicts >= 2, "no coalescing observed: {}", s.coalesced_predicts);
    assert_eq!(s.fits, 1, "coalesced predicts reuse the one fitted model");
    server.shutdown();
}

#[test]
fn shutdown_quiesces_in_flight_connections() {
    let server = start_hub_with_data();
    let addr = server.addr.to_string();

    // An in-flight connection that has already served a request...
    let mut c1 = HubClient::connect(&addr).unwrap();
    c1.stats().unwrap();

    // ...survives until another client requests shutdown.
    let mut c2 = HubClient::connect(&addr).unwrap();
    c2.shutdown().unwrap();

    // c1's next request must observe the stop flag and get a closed
    // connection, not an answer (and certainly not a hang).
    let err = c1.stats().unwrap_err();
    assert!(
        err.to_string().contains("closed"),
        "expected closed connection, got: {err:#}"
    );
    server.shutdown();
}

/// The `metrics` op round-trips the full telemetry snapshot over live
/// TCP (DESIGN.md §13): nonzero stage histograms for every stage the
/// traffic exercised, the stage-sum ≤ end-to-end consistency invariant,
/// and the counter/gauge catalog. The registry is process-wide (shared
/// by every test in this binary), so assertions stay on nonzero counts
/// and internal consistency, never exact totals.
#[test]
fn metrics_op_roundtrips_consistent_stage_histograms() {
    use c3o::hub::PipelinedClient;
    let server = start_hub_with_data();
    let addr = server.addr.to_string();
    let mut client = HubClient::connect(&addr).unwrap();

    // Exercise the stages: a cold fit (fit + cv_score), predicts, an
    // accepted submit, and a stats roundtrip.
    let rows: Vec<Vec<f64>> = (2..=6u32).map(|s| vec![s as f64, 15.0]).collect();
    client.predict_batch(JobKind::Sort, None, &rows).unwrap();
    assert!(client.submit_runs(&honest_runs(JobKind::Sort, 6, 77)).unwrap().accepted);
    client.stats().unwrap();

    let m = client.metrics().unwrap();

    // Every reactor-measured stage plus the service-layer stages the
    // traffic above drove must have recorded samples, with sane
    // percentile ordering.
    let sum_of = |name: &str| {
        let h = m.histogram(name).unwrap_or_else(|| panic!("missing histogram `{name}`"));
        assert!(h.count > 0, "{name}: zero count");
        assert!(h.p50_us <= h.p95_us, "{name}: p50 {} > p95 {}", h.p50_us, h.p95_us);
        assert!(h.p95_us <= h.p99_us, "{name}: p95 {} > p99 {}", h.p95_us, h.p99_us);
        assert!(h.p99_us <= h.max_us, "{name}: p99 {} > max {}", h.p99_us, h.max_us);
        h.sum_us
    };
    let parts = sum_of("stage_decode")
        + sum_of("stage_queue_wait")
        + sum_of("stage_service")
        + sum_of("stage_dispatch")
        + sum_of("stage_reply_write");
    let total = sum_of("stage_request_total");
    assert!(
        parts <= total,
        "stage sums must not exceed end-to-end time: {parts} > {total}"
    );
    for name in ["stage_fit", "stage_cv_score", "stage_predict"] {
        sum_of(name);
    }

    // The counter/gauge catalog is present and reflects the traffic.
    for counter in ["accepted_submits", "fits", "cache_misses", "traces_completed"] {
        let v = m.counter(counter).unwrap_or_else(|| panic!("missing counter `{counter}`"));
        assert!(v > 0, "{counter} is zero");
    }
    assert!(m.counter("idle_reaped_connections").is_some());
    assert!(m.gauge("workers_total").unwrap_or(0) >= 1);
    assert!(m.gauge("open_connections").unwrap_or(0) >= 1, "our own connection is open");

    // Rendering keeps the Prometheus naming contract.
    let text = m.render_prometheus();
    assert!(text.contains("c3o_stage_request_total_us_count"), "{text}");
    assert!(text.contains("# TYPE c3o_fits counter"), "{text}");

    // The pipelined client speaks the same op; counts are monotone.
    let mut p = PipelinedClient::connect(&addr).unwrap();
    let id = p.send_metrics().unwrap();
    let m2 = p.wait_metrics(id).unwrap();
    let before = m.histogram("stage_request_total").unwrap().count;
    let after = m2.histogram("stage_request_total").unwrap().count;
    assert!(after >= before, "stage counts went backwards: {after} < {before}");
    server.shutdown();
}

/// Trace-span lifecycle under pipelined out-of-order completion: a cold
/// Grep fit queued ahead of warm Sort hits on one connection is
/// overtaken on the wire, and every span still completes exactly once
/// with its own correlation id, `ok` verdict, and disjoint stage
/// breakdown — in reply-flush order, not submission order.
#[test]
fn trace_spans_complete_under_pipelined_out_of_order_replies() {
    use c3o::hub::PipelinedClient;
    let server = start_hub_with_data();
    let addr = server.addr.to_string();

    // Warm the Sort model so the pipelined Sort hits are cache reads.
    let rows: Vec<Vec<f64>> = (2..=6u32).map(|s| vec![s as f64, 15.0]).collect();
    let mut warmup = HubClient::connect(&addr).unwrap();
    warmup.predict_batch(JobKind::Sort, None, &rows).unwrap();

    let traces = &c3o::obs::metrics().traces;
    let completed_before = traces.completed();

    let mut p = PipelinedClient::connect(&addr).unwrap();
    // Sequential roundtrips first: they advance this connection's id
    // counter past every id other tests in this binary use, so our
    // spans are identifiable in the shared process-wide trace ring.
    for _ in 0..20 {
        let id = p.send_predict(JobKind::Sort, None, &[4.0, 15.0]).unwrap();
        p.wait_predict(id).unwrap();
    }
    let cold = p.send_predict(JobKind::Grep, None, &[4.0, 15.0, 0.01]).unwrap();
    let warm: Vec<u64> =
        rows.iter().map(|r| p.send_predict(JobKind::Sort, None, r).unwrap()).collect();
    for id in &warm {
        p.wait_predict(*id).unwrap();
    }
    // Observed server-side reordering (same mechanism the pipelining
    // test proves); the ring-order assertion below is gated on it.
    let overtaken = !p.has_reply(cold);
    p.wait_predict(cold).unwrap();

    // Spans complete moments after the reply bytes flush (the reactor
    // finishes its write pass before we can observe the reply), so poll
    // briefly for all of ours to land in the ring.
    let want: Vec<u64> = std::iter::once(cold).chain(warm.iter().copied()).collect();
    let mut ours: Vec<c3o::obs::Span> = Vec::new();
    for _ in 0..400 {
        ours = traces
            .recent()
            .into_iter()
            .filter(|s| s.op == "predict" && s.id >= cold)
            .collect();
        let mut ids: Vec<u64> = ours.iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids.dedup();
        if want.iter().all(|w| ids.binary_search(w).is_ok()) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let found: Vec<u64> = ours.iter().map(|s| s.id).collect();
    for w in &want {
        assert!(found.contains(w), "span for request id {w} never completed: {found:?}");
    }
    assert!(
        traces.completed() >= completed_before + want.len() as u64,
        "completed-span counter did not advance"
    );

    // Each span carries a correct verdict and a disjoint stage
    // breakdown: the sub-intervals never sum past the end-to-end time.
    for s in &ours {
        assert!(s.ok, "span {} ({}) reported !ok", s.id, s.op);
        let parts = s.decode_us + s.queue_us + s.service_us + s.dispatch_us + s.reply_us;
        assert!(
            parts <= s.total_us,
            "span {}: stage sum {parts} exceeds total {}",
            s.id,
            s.total_us
        );
    }

    // Completion order is reply-flush order: every overtaking warm span
    // sits before the cold fit's span in the ring.
    if overtaken {
        let pos = |id: u64| ours.iter().position(|s| s.id == id);
        let cold_pos = pos(cold).unwrap_or(usize::MAX);
        for w in &warm {
            assert!(
                pos(*w).unwrap_or(usize::MAX) < cold_pos,
                "warm span {w} completed after the cold fit despite wire reordering"
            );
        }
    }
    server.shutdown();
}
