//! E4 — the model-selection hot path.
//!
//! The paper reports 10-30 s for the leave-one-out model-selection phase
//! (Python/sklearn). This bench measures ours end-to-end and dissects it:
//!
//!   * full C3O selection (LOO over all candidates) per job,
//!   * batched LOO on the PJRT artifacts vs native per-split refits for
//!     the parametric models (the L1/L2 payoff),
//!   * single-launch latency of each artifact,
//!   * GBM fit/predict throughput (the L3-side cost).

mod common;

use std::sync::Arc;

use c3o::bench::bench;
use c3o::cloud::Catalog;
use c3o::data::JobKind;
use c3o::eval::{self};
use c3o::linalg::Matrix;
use c3o::models::{C3oPredictor, Ernest, Gbm, RuntimeModel, TrainData};
use c3o::runtime::{FitBackend, NativeBackend};
use c3o::sim::{generate_job, GeneratorConfig};
use c3o::util::prng::Pcg;

fn main() {
    let backend = common::backend();
    let native: Arc<dyn FitBackend> = Arc::new(NativeBackend::new());
    let catalog = Catalog::aws_like();

    println!("== E4: model-selection hot path ==\n");
    let mut csv = Vec::new();

    // --- Full C3O selection per job (the paper's 10-30 s phase).
    println!("C3O fit = cross-validate all candidates + refit winner:");
    for job in JobKind::ALL {
        let ds = generate_job(job, &GeneratorConfig::default(), &catalog)
            .expect("gen")
            .for_machine(eval::TARGET_MACHINE);
        let data = TrainData::from_dataset(&ds).expect("train data");
        let r = bench(&format!("c3o_fit/{job} (n={})", data.len()), 1, 5, || {
            let mut p = C3oPredictor::new(backend.clone());
            p.fit(&data).unwrap()
        });
        println!("  {}", r.per_iter_display());
        csv.push(format!("c3o_fit,{job},{},{:.6}", data.len(), r.mean_s));
    }

    // --- Batched LOO vs naive refits (Ernest, n up to 104).
    println!("\nErnest LOO: one batched artifact launch vs n native refits:");
    let mut rng = Pcg::seed(0xE4);
    for n in [16usize, 32, 64, 104] {
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![rng.range(2, 13) as f64, rng.range_f64(10.0, 30.0)])
            .collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| 20.0 + 3.0 * r[1] / r[0] + 5.0 * r[0].log2() + 0.8 * r[0])
            .collect();
        let data = TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap();

        let e_batched = Ernest::new(backend.clone());
        let rb = bench(&format!("ernest_loo_batched/{n}"), 2, 10, || {
            e_batched.loo_predictions(&data).unwrap()
        });
        // Naive: default trait implementation (n refits) on the native
        // backend — what a single-fit API would force.
        struct Naive(Arc<dyn FitBackend>);
        impl Naive {
            fn loo(&self, data: &TrainData) -> Vec<f64> {
                let mut out = Vec::new();
                for i in 0..data.len() {
                    let idx: Vec<usize> =
                        (0..data.len()).filter(|&j| j != i).collect();
                    let mut m = Ernest::new(self.0.clone());
                    m.fit(&data.subset(&idx)).unwrap();
                    out.push(m.predict_one(data.x.row(i)).unwrap());
                }
                out
            }
        }
        let naive = Naive(native.clone());
        let rn = bench(&format!("ernest_loo_refits/{n}"), 1, 5, || naive.loo(&data));
        println!("  {}", rb.per_iter_display());
        println!("  {}", rn.per_iter_display());
        println!(
            "    -> batched speedup: {:.1}x",
            rn.mean_s / rb.mean_s.max(1e-12)
        );
        csv.push(format!("ernest_loo_batched,{n},,{:.6}", rb.mean_s));
        csv.push(format!("ernest_loo_refits,{n},,{:.6}", rn.mean_s));
    }

    // --- Raw artifact launch latency.
    println!("\nartifact launch latency (padded shapes 128x8, 128 masks):");
    let x = Matrix::from_rows(
        &(0..100)
            .map(|_| (0..4).map(|_| rng.f64() + 0.1).collect::<Vec<_>>())
            .collect::<Vec<_>>(),
    )
    .unwrap();
    let yv: Vec<f64> = (0..100).map(|_| rng.f64() * 100.0).collect();
    let mut w = Matrix::zeros(100, 100);
    for i in 0..100 {
        for j in 0..100 {
            w[(i, j)] = if i == j { 0.0 } else { 1.0 };
        }
    }
    for (name, f) in [
        ("ols_batch", true),
        ("nnls_batch", false),
    ] {
        let r = bench(&format!("artifact/{name}"), 3, 20, || {
            if f {
                backend.ols_batch(&x, &yv, &w, 1e-4).unwrap()
            } else {
                backend.nnls_batch(&x, &yv, &w, 1e-4).unwrap()
            }
        });
        println!("  {}", r.per_iter_display());
        csv.push(format!("artifact_{name},100,,{:.6}", r.mean_s));
    }

    // --- GBM throughput (the native-side hot loop).
    println!("\nGBM (100 trees, depth 3):");
    let ds = generate_job(JobKind::KMeans, &GeneratorConfig::default(), &catalog)
        .expect("gen")
        .for_machine(eval::TARGET_MACHINE);
    let data = TrainData::from_dataset(&ds).expect("td");
    let r = bench(&format!("gbm_fit/{}", data.len()), 2, 10, || {
        let mut m = Gbm::with_defaults();
        m.fit(&data).unwrap();
        m
    });
    println!("  {}", r.per_iter_display());
    csv.push(format!("gbm_fit,{},,{:.6}", data.len(), r.mean_s));
    let mut m = Gbm::with_defaults();
    m.fit(&data).unwrap();
    let rp = bench("gbm_predict_batch/90", 2, 50, || m.predict(&data.x).unwrap());
    println!("  {}", rp.per_iter_display());
    csv.push(format!("gbm_predict,{},,{:.6}", data.len(), rp.mean_s));

    common::write_csv("hotpath.csv", "bench,param,extra,mean_s", &csv);

    // Headline: paper's phase took 10-30 s; ours must be << 1 s per job.
    println!("\npaper-shape check:");
    println!("  paper model-selection phase: 10-30 s (Python + sklearn, LOO)");
}
