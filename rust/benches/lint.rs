//! E14 — `c3o lint` full-tree wall time.
//!
//! The linter is a blocking CI step; it stays in the build only as long
//! as it stays cheap. This bench runs the whole v2 pipeline against the
//! real tree (`rust/src`) — lexing, function scanning, CFG + call-graph
//! construction, the interprocedural lock-set fixpoint, taint and
//! ordering passes, allow-marker filtering — and asserts the wall time
//! stays under 2 s per run (benches build with the release profile).
//!
//! The machine-readable section (`BENCH_lint.json`) records the tree
//! size the time was measured against: token / file / fn counts plus
//! finding, lock-edge and taint-flow totals, so a perf regression can
//! be told apart from the tree simply growing.

mod common;

use std::path::{Path, PathBuf};

use c3o::analysis::{self, lexer};
use c3o::bench::bench;
use c3o::util::json::Json;

/// Sum of lexed token and comment counts over every `.rs` file under
/// `root` — the input-size denominator for the timing numbers.
fn tree_tokens(root: &Path) -> (usize, usize) {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
        let Ok(entries) = std::fs::read_dir(dir) else { return };
        for e in entries.flatten() {
            let p = e.path();
            if p.is_dir() {
                walk(&p, out);
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
    }
    let mut paths = Vec::new();
    walk(root, &mut paths);
    let (mut toks, mut comments) = (0, 0);
    for p in paths {
        let src = std::fs::read_to_string(&p).expect("read source");
        let (t, c) = lexer::lex(&src);
        toks += t.len();
        comments += c.len();
    }
    (toks, comments)
}

fn main() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let (warmup, iters) = if common::smoke() { (0, 1) } else { (2, 10) };

    println!("== E14: full-tree lint wall time ==\n");

    let report = analysis::lint_dir(&root).expect("lint rust/src");
    let (tokens, comments) = tree_tokens(&root);
    println!(
        "tree: {} files, {} fns, {} tokens, {} comments",
        report.files_scanned, report.fns_scanned, tokens, comments
    );
    println!(
        "report: {} findings, {} lock edges, {} taint flows",
        report.findings.len(),
        report.lock_edges.len(),
        report.taint_flows.len()
    );
    assert!(
        report.findings.is_empty(),
        "tree must lint clean before timing it: {:?}",
        report.findings
    );

    let r = bench("lint_full_tree", warmup, iters, || {
        analysis::lint_dir(&root).expect("lint rust/src")
    });
    println!("  {}", r.per_iter_display());

    // The CI contract: a blocking lint step slower than ~2 s per run is
    // the point where people start skipping it locally.
    assert!(
        r.mean_s < 2.0,
        "full-tree lint took {:.3} s — the 2 s budget for a blocking CI step is blown",
        r.mean_s
    );

    common::write_bench_json_named(
        "BENCH_lint.json",
        "lint_full_tree",
        Json::obj(vec![
            ("files", Json::Num(report.files_scanned as f64)),
            ("fns", Json::Num(report.fns_scanned as f64)),
            ("tokens", Json::Num(tokens as f64)),
            ("comments", Json::Num(comments as f64)),
            ("findings", Json::Num(report.findings.len() as f64)),
            ("lock_edges", Json::Num(report.lock_edges.len() as f64)),
            ("taint_flows", Json::Num(report.taint_flows.len() as f64)),
            ("mean_s", Json::Num(r.mean_s)),
            ("budget_s", Json::Num(2.0)),
        ]),
    );
}
