//! E12 (config search) — catalog-wide `configure_search`: cold full-grid
//! fits vs the warm fitted-model cache.
//!
//! Cold: a fresh `PredictionService` per call pays one dynamic-selection
//! fit per sufficiently-covered machine type (the corpus covers two), on
//! the fit-path engine at 1/2/4/8 CV threads. Warm: one long-lived
//! service answers the whole (machine type × scale-out) grid from its
//! revision-keyed cache — asserted zero refits via the service's fit
//! counters, the same property `tests/api_v1.rs` checks over the wire.
//!
//! Results merge into `BENCH_config_search.json` (section
//! `config_search`). `C3O_BENCH_SMOKE=1` runs 1 iteration at reduced
//! thread coverage for CI.

mod common;

use std::sync::Arc;

use c3o::api::service::PredictionService;
use c3o::bench::bench;
use c3o::cloud::Catalog;
use c3o::configurator::UserGoals;
use c3o::cv::FitEngine;
use c3o::data::JobKind;
use c3o::hub::{HubState, Repository, ValidationPolicy};
use c3o::runtime::FitBackend;
use c3o::sim::{generate_job, GeneratorConfig};
use c3o::util::json::Json;

fn shared_state() -> Arc<HubState> {
    let catalog = Catalog::aws_like();
    let state = Arc::new(HubState::new());
    let mut repo = Repository::new(JobKind::Sort, "standard Spark sort");
    repo.maintainer_machine = Some("m5.xlarge".to_string());
    repo.data = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog)
        .expect("generate corpus");
    state.insert(repo);
    state
}

fn make_service(state: Arc<HubState>, backend: Arc<dyn FitBackend>) -> PredictionService {
    PredictionService::new(state, Catalog::aws_like(), ValidationPolicy::default(), backend)
}

fn main() {
    let backend = common::backend();
    let smoke = common::smoke();
    let state = shared_state();
    let goals = UserGoals { deadline_s: Some(900.0), confidence: 0.95 };
    let thread_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let (warmup, iters) = if smoke { (0, 1) } else { (1, 5) };

    println!("== E12: configure_search — cold full-grid fits vs warm cache ==\n");

    // Reference winner: any thread count (and the warm path) must agree.
    let reference = {
        let svc = make_service(state.clone(), backend.clone());
        svc.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap()
    };

    let mut csv = Vec::new();
    let mut summary = Vec::new();
    let mut serial_mean = 0.0f64;
    for &threads in thread_counts {
        let (st, be) = (state.clone(), backend.clone());
        let mut last = None;
        let r = bench(&format!("configure_search_cold/{threads}thr"), warmup, iters, || {
            let svc = make_service(st.clone(), be.clone());
            svc.set_engine(FitEngine::with_threads(threads));
            last = Some(svc.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap());
        });
        let got = last.expect("at least one measured iteration");
        assert_eq!(
            got.choice.machine_type, reference.choice.machine_type,
            "{threads} threads changed the winning machine type"
        );
        assert_eq!(got.choice.scale_out, reference.choice.scale_out);
        if threads == 1 {
            serial_mean = r.mean_s;
        }
        let speedup = serial_mean / r.mean_s.max(1e-12);
        println!("  {}  ({speedup:.2}x vs 1 thread)", r.per_iter_display());
        csv.push(format!("configure_search_cold,{threads},{:.6},{speedup:.3}", r.mean_s));
        summary.push(Json::obj(vec![
            ("variant", Json::Str("cold".to_string())),
            ("threads", Json::Num(threads as f64)),
            ("mean_s", Json::Num(r.mean_s)),
            ("speedup_vs_serial", Json::Num(speedup)),
        ]));
    }

    // Warm: one service, primed once — the whole grid from the cache.
    let svc = make_service(state.clone(), backend.clone());
    svc.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap();
    let fits_primed = svc.fit_stats().0;
    let (w_warm, i_warm) = if smoke { (0, 1) } else { (3, 30) };
    let r_warm = bench("configure_search_warm", w_warm, i_warm, || {
        let s = svc.configure_search(JobKind::Sort, 15.0, vec![], &goals).unwrap();
        assert_eq!(s.choice.scale_out, reference.choice.scale_out);
    });
    let (fits, hits, _) = svc.fit_stats();
    assert_eq!(fits, fits_primed, "warm full-grid search must never refit");
    println!("  {}  ({fits} fits total, {hits} cache hits)", r_warm.per_iter_display());
    csv.push(format!("configure_search_warm,-,{:.6},", r_warm.mean_s));
    summary.push(Json::obj(vec![
        ("variant", Json::Str("warm".to_string())),
        ("mean_s", Json::Num(r_warm.mean_s)),
        ("fits", Json::Num(fits as f64)),
        ("cache_hits", Json::Num(hits as f64)),
    ]));

    common::write_csv("config_search.csv", "bench,threads,mean_s,speedup", &csv);
    common::write_bench_json_named(
        "BENCH_config_search.json",
        "config_search",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke)),
            ("winner", Json::Str(reference.choice.machine_type.clone())),
            ("scale_out", Json::Num(reference.choice.scale_out as f64)),
            ("rows", Json::Arr(summary)),
        ]),
    );
}
