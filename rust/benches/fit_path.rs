//! E4 (fit path) — cold-fit latency vs CV threads at repo scale.
//!
//! One cold `C3oPredictor::fit` at the paper's 930-experiment corpus
//! scale cross-validates every candidate (k-fold here: 930 > loo_cap),
//! which PR 2 left as the hub's remaining serial bottleneck. This bench
//! drives the `cv::parallel::FitEngine` at 1/2/4/8 threads on one
//! 930-record training set and reports the speedup over the serial
//! reference, plus two budgeted rows (point cap and wall-clock cap)
//! showing the LOO → k-fold → reduced-set degrade.
//!
//! The engine guarantees bit-identical scores at any thread count, so the
//! bench asserts the chosen model and its MAPE bits match the serial run
//! while timing it.
//!
//! Results merge into `BENCH_fit_path.json` (section `fit_path`).
//! `C3O_BENCH_SMOKE=1` runs 1 iteration at reduced scale for CI.

mod common;

use std::sync::Arc;

use c3o::bench::bench;
use c3o::cv::{FitEngine, SampleStrategy, SelectionBudget, SelectionPlan};
use c3o::linalg::Matrix;
use c3o::models::{C3oPredictor, TrainData};
use c3o::runtime::FitBackend;
use c3o::util::json::Json;
use c3o::util::prng::Pcg;

/// A 930-row training world shaped like the paper's corpus: scale-outs
/// 2..12, data sizes 10..50 GB, one context feature, separable runtime
/// with mild noise.
fn corpus(n: usize, seed: u64) -> TrainData {
    let mut rng = Pcg::seed(seed);
    let mut rows = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let s = (2 + i % 11) as f64;
        let d = rng.range_f64(10.0, 50.0);
        let k = rng.range(3, 10) as f64;
        rows.push(vec![s, d, k]);
        y.push(
            (1.0 / s + 0.02 * s)
                * (10.0 + 4.0 * d + 9.0 * k)
                * (1.0 + 0.02 * rng.normal()),
        );
    }
    TrainData::new(Matrix::from_rows(&rows).unwrap(), y).unwrap()
}

fn cold_fit(
    backend: &Arc<dyn FitBackend>,
    data: &TrainData,
    engine: FitEngine,
) -> (String, u64) {
    let mut p = C3oPredictor::new(backend.clone());
    p.set_engine(engine);
    let report = p.fit(data).expect("cold fit");
    (report.chosen, report.chosen_score.mape.to_bits())
}

fn main() {
    let backend = common::backend();
    let smoke = common::smoke();
    let n = if smoke { 160 } else { 930 };
    let (warmup, iters) = if smoke { (0, 1) } else { (1, 5) };
    let thread_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let data = corpus(n, 0xC30);

    println!("== E4 (fit path): cold fit at {n}-record repo scale ==\n");

    // Serial reference: timing baseline + the ground-truth selection.
    let (chosen_serial, mape_bits_serial) =
        cold_fit(&backend, &data, FitEngine::serial());

    let mut summary = Vec::new();
    let mut csv = Vec::new();
    let mut serial_mean = 0.0f64;
    for &threads in thread_counts {
        // Capture the last measured iteration's selection instead of
        // paying one more untimed cold fit just to assert on it.
        let mut last = (String::new(), 0u64);
        let r = bench(&format!("cold_fit/{n}pts/{threads}thr"), warmup, iters, || {
            last = cold_fit(&backend, &data, FitEngine::with_threads(threads));
        });
        // Any thread count must reproduce the serial selection exactly.
        let (chosen, mape_bits) = last;
        assert_eq!(chosen, chosen_serial, "{threads} threads changed the winner");
        assert_eq!(mape_bits, mape_bits_serial, "{threads} threads changed the score");

        if threads == 1 {
            serial_mean = r.mean_s;
        }
        let speedup = serial_mean / r.mean_s.max(1e-12);
        println!("  {}  ({speedup:.2}x vs 1 thread)", r.per_iter_display());
        csv.push(format!("cold_fit,{n},{threads},{:.6},{speedup:.3}", r.mean_s));
        summary.push(Json::obj(vec![
            ("records", Json::Num(n as f64)),
            ("threads", Json::Num(threads as f64)),
            ("mean_s", Json::Num(r.mean_s)),
            ("speedup_vs_serial", Json::Num(speedup)),
            ("chosen", Json::Str(chosen)),
        ]));
    }

    // Budget degrade rows: a hard point cap and a tight wall-clock cap.
    println!("\n  -- selection budget (LOO → k-fold → reduced set) --");
    for (label, budget) in [
        (
            "points<=120",
            SelectionBudget {
                max_points: Some(120),
                strategy: SampleStrategy::StratifiedByScaleOut,
                ..SelectionBudget::default()
            },
        ),
        (
            "wall<=0.5s",
            SelectionBudget { max_seconds: Some(0.5), ..SelectionBudget::default() },
        ),
    ] {
        let engine = FitEngine { threads: 0, budget };
        // Capture the last measured fit's report rather than refitting
        // once more outside the timer.
        let mut last: Option<(String, SelectionPlan)> = None;
        let r = bench(&format!("cold_fit_budget/{n}pts/{label}"), warmup, iters, || {
            let mut p = C3oPredictor::new(backend.clone());
            p.set_engine(engine.clone());
            let report = p.fit(&data).expect("budgeted fit");
            last = Some((report.chosen, report.plan));
        });
        let (chosen, plan) = last.expect("at least one measured iteration");
        println!(
            "  {}  (plan: {:?} on {}/{} points)",
            r.per_iter_display(),
            plan.method,
            plan.n_used,
            plan.n_total
        );
        csv.push(format!("cold_fit_budget,{n},{label},{:.6},", r.mean_s));
        summary.push(Json::obj(vec![
            ("records", Json::Num(n as f64)),
            ("budget", Json::Str(label.to_string())),
            ("mean_s", Json::Num(r.mean_s)),
            ("cv_points", Json::Num(plan.n_used as f64)),
            ("chosen", Json::Str(chosen)),
        ]));
    }

    common::write_csv("fit_path.csv", "bench,records,variant,mean_s,speedup", &csv);
    common::write_bench_json_named(
        "BENCH_fit_path.json",
        "fit_path",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke)),
            ("rows", Json::Arr(summary)),
        ]),
    );
}
