//! E13 — hub replication: follower catch-up throughput and read scaling.
//!
//! Two measurements over real TCP (DESIGN.md §11):
//!
//!   * catch-up — a leader holds N WAL revisions; a fresh follower tails
//!     the whole log through `repl_fetch` + the validation-free apply
//!     path. Reported as WAL records/s and data rows/s.
//!   * read scaling — warm `predict_batch` served by 1 leader alone vs
//!     the same client load spread over 1 leader + 2 converged followers.
//!     The fitted-model cache is revision-keyed, so every hub answers
//!     from its own warm cache and read capacity should scale with hubs.
//!
//! Results merge into `BENCH_replication.json` (section `replication`).
//! `C3O_BENCH_SMOKE=1` shrinks sizes for CI.

mod common;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use c3o::api::service::PredictionService;
use c3o::cloud::Catalog;
use c3o::data::{Dataset, JobKind, RunRecord};
use c3o::hub::{HubClient, HubServer, HubState, Repository, ValidationPolicy};
use c3o::replication::sync_once;
use c3o::storage::{DurableStore, FsyncPolicy, StorageConfig};
use c3o::util::json::Json;

const RECORDS_PER_SUBMIT: usize = 4;

/// Unique records per submission (bootstrap regime: the gate never arms,
/// so the measured cost is replication, not GBM fits).
fn contribution(i: usize) -> Dataset {
    let mut ds = Dataset::new(JobKind::Sort);
    for k in 0..RECORDS_PER_SUBMIT {
        let n = (i * RECORDS_PER_SUBMIT + k) as f64;
        ds.push(RunRecord {
            machine_type: "m5.xlarge".into(),
            scale_out: 2 + ((i * RECORDS_PER_SUBMIT + k) % 11) as u32,
            data_size_gb: 10.0 + n * 1e-3,
            context: vec![],
            runtime_s: 100.0 + n * 1e-3,
        })
        .expect("valid record");
    }
    ds
}

fn policy() -> ValidationPolicy {
    ValidationPolicy { min_existing: usize::MAX, ..Default::default() }
}

fn bench_state() -> Arc<HubState> {
    let state = Arc::new(HubState::new());
    let mut repo = Repository::new(JobKind::Sort, "replication bench repo");
    repo.maintainer_machine = Some("m5.xlarge".to_string());
    state.insert(repo);
    state
}

fn service_on(state: Arc<HubState>) -> Arc<PredictionService> {
    Arc::new(PredictionService::new(state, Catalog::aws_like(), policy(), common::backend()))
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("c3o_bench_replication_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// An in-memory follower converged with `leader` by tailing its full log.
fn converged_follower(leader: &str) -> Arc<PredictionService> {
    let service = service_on(bench_state());
    service.set_follower_of(leader);
    let mut client = HubClient::connect(leader).expect("connect follower");
    sync_once(&service, &mut client, 256).expect("follower sync");
    service
}

/// `reqs` warm predict_batch calls per thread, spread round-robin over
/// `targets`; returns aggregate requests/s.
fn read_load(targets: &[String], threads: usize, reqs: usize) -> f64 {
    let rows: Vec<Vec<f64>> = (2..=12).map(|s| vec![s as f64, 15.0]).collect();
    // Warm every hub's fitted-model cache outside the timed window.
    for addr in targets {
        let mut c = HubClient::connect(addr).expect("warm connect");
        c.predict_batch(JobKind::Sort, None, &rows).expect("warm predict");
    }
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..threads {
            let rows = &rows;
            scope.spawn(move || {
                let mut clients: Vec<HubClient> = targets
                    .iter()
                    .map(|a| HubClient::connect(a).expect("connect"))
                    .collect();
                for i in 0..reqs {
                    // Offset by thread id so threads do not march in
                    // lockstep over the same hub.
                    let c = &mut clients[(i + t) % targets.len()];
                    c.predict_batch(JobKind::Sort, None, rows).expect("predict");
                }
            });
        }
    });
    (threads * reqs) as f64 / t0.elapsed().as_secs_f64().max(1e-12)
}

fn main() {
    let smoke = common::smoke();
    let submits = if smoke { 16 } else { 200 };
    let threads = if smoke { 2 } else { 8 };
    let reqs = if smoke { 10 } else { 200 };
    println!("== E13: hub replication — catch-up throughput and read scaling ==");
    println!("   ({submits} leader revisions x {RECORDS_PER_SUBMIT} records)\n");

    // Leader: durable store (the WAL is the replication log), real TCP.
    let dir = fresh_dir("leader");
    let state = bench_state();
    let (store, recovered) =
        DurableStore::open(&dir, StorageConfig { fsync: FsyncPolicy::Never, snapshot_every: 0 })
            .expect("open store");
    assert!(recovered.is_empty());
    state.set_storage(Arc::new(store)).expect("attach store");
    for i in 0..submits {
        let (verdict, _) = state.submit(contribution(i), &policy()).expect("submit");
        assert!(verdict.accepted, "{}", verdict.reason);
    }
    let leader = HubServer::start("127.0.0.1:0", service_on(state)).expect("start leader");
    let leader_addr = leader.addr.to_string();

    // Catch-up: a fresh follower tails the whole log over TCP.
    let follower = service_on(bench_state());
    follower.set_follower_of(leader_addr.as_str());
    let mut client = HubClient::connect(&leader_addr).expect("connect");
    let t0 = Instant::now();
    let applied = sync_once(&follower, &mut client, 256).expect("catch-up sync");
    let catch_up_s = t0.elapsed().as_secs_f64().max(1e-12);
    assert_eq!(applied, submits as u64, "full log applied");
    assert_eq!(follower.state().revision(JobKind::Sort), Some(submits as u64));
    let records_per_s = submits as f64 / catch_up_s;
    let rows_per_s = (submits * RECORDS_PER_SUBMIT) as f64 / catch_up_s;
    println!("  catch-up: {submits} revisions in {catch_up_s:.3}s");
    println!("            {records_per_s:>10.0} WAL records/s  {rows_per_s:>10.0} rows/s");

    // Read scaling: leader alone vs leader + 2 converged followers.
    let fa = HubServer::start("127.0.0.1:0", converged_follower(&leader_addr))
        .expect("start follower A");
    let fb = HubServer::start("127.0.0.1:0", converged_follower(&leader_addr))
        .expect("start follower B");
    let leader_only = vec![leader_addr.clone()];
    let spread =
        vec![leader_addr.clone(), fa.addr.to_string(), fb.addr.to_string()];
    let solo_rps = read_load(&leader_only, threads, reqs);
    let spread_rps = read_load(&spread, threads, reqs);
    let scaling = spread_rps / solo_rps.max(1e-12);
    println!("\n  reads ({threads} threads x {reqs} predict_batch):");
    println!("  1 leader                       {solo_rps:>10.0} req/s");
    println!("  1 leader + 2 followers         {spread_rps:>10.0} req/s  ({scaling:.2}x)");

    common::write_bench_json_named(
        "BENCH_replication.json",
        "replication",
        Json::obj(vec![
            ("submits", Json::Num(submits as f64)),
            ("records_per_submit", Json::Num(RECORDS_PER_SUBMIT as f64)),
            ("catch_up_records_per_s", Json::Num(records_per_s)),
            ("catch_up_rows_per_s", Json::Num(rows_per_s)),
            ("read_threads", Json::Num(threads as f64)),
            ("read_reqs_per_thread", Json::Num(reqs as f64)),
            ("reads_leader_only_rps", Json::Num(solo_rps)),
            ("reads_with_followers_rps", Json::Num(spread_rps)),
            ("read_scaling_x", Json::Num(scaling)),
        ]),
    );

    fa.shutdown();
    fb.shutdown();
    leader.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
