//! E11 — hub durability: what the WAL costs on the submit path, and
//! proof that crash recovery holds at bench scale.
//!
//! Drives `HubState::submit` (the real acceptance path, §III-C-b gate in
//! bootstrap regime so validation cost does not mask I/O cost) under
//! three configurations:
//!
//!   * in-memory — the pre-storage hub: acknowledged writes die with the
//!     process (the old behavior this subsystem removes),
//!   * WAL, fsync never — append reaches the kernel before the ack;
//!     survives process crash (kill -9), not OS crash,
//!   * WAL, fsync always — fsync before every ack; survives power loss.
//!
//! Afterwards the fsync-never data dir is reopened as a crashed process
//! would find it — including once with a deliberately torn trailing
//! record — and every acknowledged contribution must be recovered.
//!
//! Results merge into `BENCH_hub_durability.json` (section
//! `hub_durability`). `C3O_BENCH_SMOKE=1` shrinks the submit count for
//! CI.

mod common;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use c3o::data::{Dataset, JobKind, RunRecord};
use c3o::hub::{HubState, Repository, ValidationPolicy};
use c3o::storage::{DurableStore, FsyncPolicy, StorageConfig};
use c3o::util::json::Json;

const RECORDS_PER_SUBMIT: usize = 4;

/// Unique records per submission — unique (scale-out, size, runtime)
/// triples so neither the duplicate-replay gate nor the schema gate
/// interferes with the I/O measurement.
fn contribution(i: usize) -> Dataset {
    let mut ds = Dataset::new(JobKind::Sort);
    for k in 0..RECORDS_PER_SUBMIT {
        let n = (i * RECORDS_PER_SUBMIT + k) as f64;
        ds.push(RunRecord {
            machine_type: "m5.xlarge".into(),
            scale_out: 2 + ((i * RECORDS_PER_SUBMIT + k) % 11) as u32,
            data_size_gb: 10.0 + n * 1e-3,
            context: vec![],
            runtime_s: 100.0 + n * 1e-3,
        })
        .expect("valid record");
    }
    ds
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("c3o_bench_durability_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Run `submits` acceptances and return aggregate submits/sec (plus the
/// data dir when durable, for the recovery phase).
fn run_mode(tag: &str, submits: usize, durable: Option<FsyncPolicy>) -> (f64, Option<PathBuf>) {
    let state = HubState::new();
    state.insert(Repository::new(JobKind::Sort, "bench repo"));
    // Bootstrap regime: the retrain gate never arms, so the measured cost
    // is submit bookkeeping + WAL I/O, not GBM fits.
    let policy = ValidationPolicy { min_existing: usize::MAX, ..Default::default() };
    let mut dir_out = None;
    if let Some(fsync) = durable {
        let dir = fresh_dir(tag);
        let (store, recovered) =
            DurableStore::open(&dir, StorageConfig { fsync, snapshot_every: 0 })
                .expect("open store");
        assert!(recovered.is_empty());
        state.set_storage(Arc::new(store)).expect("attach store");
        dir_out = Some(dir);
    }
    let t0 = Instant::now();
    for i in 0..submits {
        let (verdict, revision) = state.submit(contribution(i), &policy).expect("submit");
        assert!(verdict.accepted, "{}", verdict.reason);
        assert_eq!(revision, (i + 1) as u64);
    }
    let rps = submits as f64 / t0.elapsed().as_secs_f64().max(1e-12);
    (rps, dir_out)
}

fn main() {
    let smoke = common::smoke();
    let submits = if smoke { 24 } else { 300 };
    println!("== E11: hub durability — WAL + fsync cost on the submit path ==");
    println!("   ({submits} submits x {RECORDS_PER_SUBMIT} records)\n");

    let (mem_rps, _) = run_mode("mem", submits, None);
    println!("  in-memory (lossy)              {mem_rps:>10.0} submits/s");

    let (never_rps, dir_never) = run_mode("never", submits, Some(FsyncPolicy::Never));
    println!("  WAL, fsync never               {never_rps:>10.0} submits/s");

    let (always_rps, dir_always) = run_mode("always", submits, Some(FsyncPolicy::Always));
    println!("  WAL, fsync always              {always_rps:>10.0} submits/s");
    println!(
        "\n  -> WAL overhead {:.1}% (no fsync); fsync-always costs {:.1}x vs WAL alone",
        (mem_rps / never_rps - 1.0) * 100.0,
        never_rps / always_rps.max(1e-12),
    );

    // Crash recovery at bench scale: reopen the fsync-never dir exactly as
    // a killed process left it — no sync, no snapshot ever ran.
    let dir = dir_never.expect("durable dir");
    let (_, recovered) =
        DurableStore::open(&dir, StorageConfig::default()).expect("recover");
    let sort = recovered.iter().find(|r| r.job == JobKind::Sort).expect("sort repo");
    assert_eq!(sort.revision, submits as u64, "revision watermark recovered");
    assert_eq!(
        sort.data.len(),
        submits * RECORDS_PER_SUBMIT,
        "every acknowledged contribution recovered"
    );

    // Kill -9 mid-append: tear the WAL tail, reopen, acknowledged records
    // must all survive and the torn bytes must be truncated away.
    let wal_path = dir.join("wal").join("sort.wal");
    let mut bytes = std::fs::read(&wal_path).expect("read wal");
    let clean_len = bytes.len() as u64;
    bytes.extend_from_slice(&[0x5A; 13]);
    std::fs::write(&wal_path, &bytes).expect("tear wal");
    let (store, recovered) =
        DurableStore::open(&dir, StorageConfig::default()).expect("recover torn");
    assert_eq!(store.torn_tails(), 1, "torn tail detected");
    let sort = recovered.iter().find(|r| r.job == JobKind::Sort).expect("sort repo");
    assert_eq!(sort.data.len(), submits * RECORDS_PER_SUBMIT, "no acknowledged loss");
    assert_eq!(std::fs::metadata(&wal_path).expect("stat").len(), clean_len);
    println!(
        "  recovery: {} submits replayed intact, torn trailing record truncated",
        submits
    );

    common::write_bench_json_named(
        "BENCH_hub_durability.json",
        "hub_durability",
        Json::obj(vec![
            ("submits", Json::Num(submits as f64)),
            ("records_per_submit", Json::Num(RECORDS_PER_SUBMIT as f64)),
            ("in_memory_rps", Json::Num(mem_rps)),
            ("wal_no_fsync_rps", Json::Num(never_rps)),
            ("wal_fsync_always_rps", Json::Num(always_rps)),
            (
                "wal_overhead_pct",
                Json::Num((mem_rps / never_rps.max(1e-12) - 1.0) * 100.0),
            ),
            ("recovery_ok", Json::Bool(true)),
        ]),
    );

    std::fs::remove_dir_all(&dir).ok();
    if let Some(d) = dir_always {
        std::fs::remove_dir_all(&d).ok();
    }
}
