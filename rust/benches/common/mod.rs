//! Shared helpers for the bench binaries.

// Each bench binary compiles this module separately and uses a subset of
// the helpers; the unused ones are not dead code.
#![allow(dead_code)]

use std::path::PathBuf;
use std::sync::Arc;

use c3o::runtime::{Engine, FitBackend, NativeBackend};
use c3o::util::json::Json;

/// Splits per evaluation cell: the paper uses 300; override with
/// C3O_SPLITS for quick runs.
pub fn splits() -> usize {
    std::env::var("C3O_SPLITS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300)
}

/// results/ directory (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    std::fs::create_dir_all(&dir).expect("mkdir results/");
    dir
}

/// Write a CSV file under results/.
pub fn write_csv(name: &str, header: &str, rows: &[String]) {
    let path = results_dir().join(name);
    let mut text = String::from(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(&path, text).expect("write csv");
    println!("[bench] wrote {}", path.display());
}

/// Merge one section into `BENCH_hub_load.json` at the crate root — the
/// machine-readable perf summary tracked across PRs. Each bench binary
/// owns one top-level key and re-writing it leaves the others intact, so
/// `cargo bench` runs accumulate into a single file.
pub fn write_bench_json(section: &str, value: Json) {
    let path = PathBuf::from("BENCH_hub_load.json");
    let mut root = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .unwrap_or_else(|| Json::Obj(Default::default()));
    if !matches!(root, Json::Obj(_)) {
        root = Json::Obj(Default::default());
    }
    if let Json::Obj(map) = &mut root {
        map.insert(section.to_string(), value);
    }
    let mut text = root.to_string();
    text.push('\n');
    std::fs::write(&path, text).expect("write bench json");
    println!("[bench] wrote section `{section}` to {}", path.display());
}

/// The production backend if artifacts exist, else native (announced).
pub fn backend() -> Arc<dyn FitBackend> {
    match Engine::load_default() {
        Ok(e) => {
            println!("[bench] backend: pjrt ({})", e.artifact_dir().display());
            Arc::new(e)
        }
        Err(e) => {
            println!("[bench] backend: native ({e:#})");
            Arc::new(NativeBackend::new())
        }
    }
}
