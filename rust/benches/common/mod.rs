//! Shared helpers for the bench binaries.

use std::path::PathBuf;
use std::sync::Arc;

use c3o::runtime::{Engine, FitBackend, NativeBackend};

/// Splits per evaluation cell: the paper uses 300; override with
/// C3O_SPLITS for quick runs.
pub fn splits() -> usize {
    std::env::var("C3O_SPLITS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300)
}

/// results/ directory (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    std::fs::create_dir_all(&dir).expect("mkdir results/");
    dir
}

/// Write a CSV file under results/.
pub fn write_csv(name: &str, header: &str, rows: &[String]) {
    let path = results_dir().join(name);
    let mut text = String::from(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(&path, text).expect("write csv");
    println!("[bench] wrote {}", path.display());
}

/// The production backend if artifacts exist, else native (announced).
pub fn backend() -> Arc<dyn FitBackend> {
    match Engine::load_default() {
        Ok(e) => {
            println!("[bench] backend: pjrt ({})", e.artifact_dir().display());
            Arc::new(e)
        }
        Err(e) => {
            println!("[bench] backend: native ({e:#})");
            Arc::new(NativeBackend::new())
        }
    }
}
