//! Shared helpers for the bench binaries.

// Each bench binary compiles this module separately and uses a subset of
// the helpers; the unused ones are not dead code.
#![allow(dead_code)]

use std::path::PathBuf;
use std::sync::Arc;

use c3o::runtime::{Engine, FitBackend, NativeBackend};
use c3o::util::json::Json;

/// Splits per evaluation cell: the paper uses 300; override with
/// C3O_SPLITS for quick runs.
pub fn splits() -> usize {
    std::env::var("C3O_SPLITS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300)
}

/// results/ directory (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    std::fs::create_dir_all(&dir).expect("mkdir results/");
    dir
}

/// Write a CSV file under results/.
pub fn write_csv(name: &str, header: &str, rows: &[String]) {
    let path = results_dir().join(name);
    let mut text = String::from(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(&path, text).expect("write csv");
    println!("[bench] wrote {}", path.display());
}

/// Merge one section into a bench JSON at the crate root — the
/// machine-readable perf summaries tracked across PRs. Each bench binary
/// owns one top-level key of one file and re-writing it leaves the other
/// sections intact, so `cargo bench` runs accumulate.
pub fn write_bench_json_named(file: &str, section: &str, value: Json) {
    let path = PathBuf::from(file);
    let mut root = std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .unwrap_or_else(|| Json::Obj(Default::default()));
    if !matches!(root, Json::Obj(_)) {
        root = Json::Obj(Default::default());
    }
    if let Json::Obj(map) = &mut root {
        map.insert(section.to_string(), value);
    }
    let mut text = root.to_string();
    text.push('\n');
    std::fs::write(&path, text).expect("write bench json");
    println!("[bench] wrote section `{section}` to {}", path.display());
}

/// The hub-path benches (E8/E9) share `BENCH_hub_load.json`.
pub fn write_bench_json(section: &str, value: Json) {
    write_bench_json_named("BENCH_hub_load.json", section, value);
}

/// CI smoke mode (`C3O_BENCH_SMOKE=1`): 1 measured iteration, shrunken
/// problem sizes — keeps bench binaries compiling *and running* in CI
/// without burning minutes.
pub fn smoke() -> bool {
    std::env::var("C3O_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// The production backend if artifacts exist, else native (announced).
pub fn backend() -> Arc<dyn FitBackend> {
    match Engine::load_default() {
        Ok(e) => {
            println!("[bench] backend: pjrt ({})", e.artifact_dir().display());
            Arc::new(e)
        }
        Err(e) => {
            println!("[bench] backend: native ({e:#})");
            Arc::new(NativeBackend::new())
        }
    }
}
