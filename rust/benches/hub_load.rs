//! E9 — hub load: requests/sec through the TCP worker-pool server.
//!
//! Drives a live [`HubServer`] with K concurrent clients issuing
//! `predict_batch` frames and reports aggregate throughput:
//!
//!   * cold — fresh server per sample: the first request pays the full
//!     dynamic model-selection fit,
//!   * warm — one long-lived server, primed once: every request is
//!     answered from the sharded fitted-model cache (asserted: zero
//!     refits), measured at 1, 2, 4 and 8 concurrent clients.
//!
//! A single client is latency-bound (write → server → read ping-pong);
//! the worker pool + striped cache let K clients overlap those cycles, so
//! warm throughput should scale with the client count. Results land in
//! `BENCH_hub_load.json` (section `hub_load`) so the perf trajectory is
//! tracked across PRs.

mod common;

use std::sync::Arc;
use std::time::Instant;

use c3o::api::service::PredictionService;
use c3o::cloud::Catalog;
use c3o::data::JobKind;
use c3o::hub::{
    HubClient, HubServer, HubState, Repository, ServerConfig, ValidationPolicy,
};
use c3o::runtime::FitBackend;
use c3o::sim::{generate_job, GeneratorConfig};
use c3o::util::json::Json;

const ROWS_PER_REQUEST: usize = 8;
const WARM_TOTAL_REQS: usize = 400;
const CLIENT_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn service(backend: Arc<dyn FitBackend>) -> Arc<PredictionService> {
    let catalog = Catalog::aws_like();
    let state = Arc::new(HubState::new());
    let mut repo = Repository::new(JobKind::Sort, "standard Spark sort");
    repo.maintainer_machine = Some("m5.xlarge".to_string());
    repo.data = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog)
        .expect("generate corpus");
    state.insert(repo);
    Arc::new(PredictionService::new(state, catalog, ValidationPolicy::default(), backend))
}

fn rows() -> Vec<Vec<f64>> {
    (0..ROWS_PER_REQUEST)
        .map(|i| vec![2.0 + (i % 11) as f64, 10.0 + (i % 20) as f64])
        .collect()
}

/// Drive `reqs_per_client` warm `predict_batch` requests from `clients`
/// concurrent connections; returns aggregate requests/sec.
fn drive(addr: &str, clients: usize, reqs_per_client: usize) -> f64 {
    let rows = rows();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..clients {
            scope.spawn(|| {
                let mut c = HubClient::connect(addr).expect("connect");
                for _ in 0..reqs_per_client {
                    let b = c.predict_batch(JobKind::Sort, None, &rows).expect("predict");
                    assert!(b.cached, "load loop must stay on the warm path");
                }
            });
        }
    });
    (clients * reqs_per_client) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let backend = common::backend();
    println!("== E9: hub load — worker-pool throughput over TCP ==\n");

    // Cold: fresh server per sample; the first predict_batch pays the fit.
    let mut cold = Vec::new();
    for _ in 0..3 {
        let svc = service(backend.clone());
        let server = HubServer::start_with(
            "127.0.0.1:0",
            svc,
            ServerConfig { workers: 8, max_conns: 256, ..ServerConfig::default() },
        )
        .expect("start hub");
        let mut c = HubClient::connect(&server.addr.to_string()).expect("connect");
        let t0 = Instant::now();
        let b = c.predict_batch(JobKind::Sort, None, &rows()).expect("predict");
        assert!(!b.cached, "first request on a fresh server must be a cold fit");
        cold.push(t0.elapsed().as_secs_f64());
        server.shutdown();
    }
    let cold_mean = cold.iter().sum::<f64>() / cold.len() as f64;
    println!(
        "  cold predict_batch (fit incl.)   {:>10.1} ms/req  ({:>7.1} req/s)",
        cold_mean * 1e3,
        1.0 / cold_mean
    );

    // Warm: one server, primed once, then driven at increasing K.
    let svc = service(backend.clone());
    let server = HubServer::start_with(
        "127.0.0.1:0",
        svc,
        ServerConfig { workers: 16, max_conns: 256, ..ServerConfig::default() },
    )
    .expect("start hub");
    let addr = server.addr.to_string();
    let mut prime = HubClient::connect(&addr).expect("connect");
    prime.predict_batch(JobKind::Sort, None, &rows()).expect("prime");
    drop(prime);
    drive(&addr, 1, 50); // unmeasured warmup of the whole path

    let mut per_k: Vec<(usize, f64)> = Vec::new();
    for &k in &CLIENT_COUNTS {
        let rps = drive(&addr, k, WARM_TOTAL_REQS / k);
        println!("  warm predict_batch, {k:>2} client(s)  {rps:>10.0} req/s");
        per_k.push((k, rps));
    }
    let rps1 = per_k[0].1;
    let rps_max = per_k.last().unwrap().1;
    let scaling = rps_max / rps1.max(1e-12);
    println!("\n  -> warm scaling, {} clients vs 1: {scaling:.2}x", CLIENT_COUNTS[3]);

    // The whole warm phase must have been served by the single primed fit.
    let mut c = HubClient::connect(&addr).expect("connect");
    let stats = c.stats().expect("stats");
    assert_eq!(stats.fits, 1, "warm load loop must never refit");
    server.shutdown();

    let warm: Vec<Json> = per_k
        .iter()
        .map(|&(k, rps)| {
            Json::obj(vec![
                ("clients", Json::Num(k as f64)),
                ("rps", Json::Num(rps)),
            ])
        })
        .collect();
    common::write_bench_json(
        "hub_load",
        Json::obj(vec![
            ("job", Json::Str("sort".to_string())),
            ("rows_per_request", Json::Num(ROWS_PER_REQUEST as f64)),
            ("cold_s_per_req", Json::Num(cold_mean)),
            ("cold_rps", Json::Num(1.0 / cold_mean)),
            ("warm", Json::Arr(warm)),
            ("warm_scaling_8_vs_1", Json::Num(scaling)),
        ]),
    );
}
