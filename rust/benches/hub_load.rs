//! E9 — hub load: requests/sec through the reactor-transport TCP server.
//!
//! Drives a live [`HubServer`] and reports, per phase:
//!
//!   * cold — fresh server per sample: the first request pays the full
//!     dynamic model-selection fit,
//!   * warm — one long-lived server, primed once: every request is
//!     answered from the sharded fitted-model cache (asserted: zero
//!     refits), measured at 1, 2, 4 and 8 concurrent clients,
//!   * pipelined — one connection keeping a sliding window of requests
//!     in flight vs the strict write→read roundtrip of `HubClient`,
//!   * idle connections — hundreds of mostly-idle pipelined connections
//!     parked on the reactor while a handful of active clients measure
//!     warm-predict p50/p99 latency and aggregate throughput,
//!   * coalescing — concurrent single-row `predict`s folded into batched
//!     model calls under a small coalescing window,
//!   * telemetry — after the herd run, the `metrics` op must return
//!     internally consistent per-stage histograms (nonzero counts,
//!     disjoint stage sums ≤ end-to-end); the rendered Prometheus text
//!     lands in `BENCH_hub_metrics.prom` for the CI artifact, plus a
//!     per-record cost probe of the histogram instrument itself.
//!
//! A single roundtrip client is latency-bound; the reactor + worker pool
//! let concurrent clients (or one pipelined connection) overlap those
//! cycles. Results land in `BENCH_hub_load.json` (section `hub_load`) so
//! the perf trajectory is tracked across PRs; `C3O_BENCH_SMOKE=1` shrinks
//! request counts (but keeps the full idle-connection herd) for CI.

mod common;

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

use c3o::api::proto::{BatchPrediction, Op};
use c3o::api::service::PredictionService;
use c3o::cloud::Catalog;
use c3o::data::JobKind;
use c3o::hub::{
    HubClient, HubServer, HubState, PipelinedClient, Repository, ServerConfig, ValidationPolicy,
};
use c3o::runtime::FitBackend;
use c3o::sim::{generate_job, GeneratorConfig};
use c3o::util::json::Json;

const ROWS_PER_REQUEST: usize = 8;
const WARM_TOTAL_REQS: usize = 400;
const CLIENT_COUNTS: [usize; 4] = [1, 2, 4, 8];
const PIPELINE_DEPTH: usize = 32;
const IDLE_CONNS: usize = 256;

fn service(backend: Arc<dyn FitBackend>) -> Arc<PredictionService> {
    let catalog = Catalog::aws_like();
    let state = Arc::new(HubState::new());
    let mut repo = Repository::new(JobKind::Sort, "standard Spark sort");
    repo.maintainer_machine = Some("m5.xlarge".to_string());
    repo.data = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog)
        .expect("generate corpus");
    state.insert(repo);
    Arc::new(PredictionService::new(state, catalog, ValidationPolicy::default(), backend))
}

fn rows() -> Vec<Vec<f64>> {
    (0..ROWS_PER_REQUEST)
        .map(|i| vec![2.0 + (i % 11) as f64, 10.0 + (i % 20) as f64])
        .collect()
}

/// Drive `reqs_per_client` warm `predict_batch` requests from `clients`
/// concurrent roundtrip connections; returns aggregate requests/sec.
fn drive(addr: &str, clients: usize, reqs_per_client: usize) -> f64 {
    let rows = rows();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for _ in 0..clients {
            scope.spawn(|| {
                let mut c = HubClient::connect(addr).expect("connect");
                for _ in 0..reqs_per_client {
                    let b = c.predict_batch(JobKind::Sort, None, &rows).expect("predict");
                    assert!(b.cached, "load loop must stay on the warm path");
                }
            });
        }
    });
    (clients * reqs_per_client) as f64 / t0.elapsed().as_secs_f64()
}

/// Drive `total` warm `predict_batch` requests through ONE pipelined
/// connection with a sliding window of `depth` in-flight requests;
/// returns requests/sec.
fn drive_pipelined(addr: &str, total: usize, depth: usize) -> f64 {
    let rows = rows();
    let mut p = PipelinedClient::connect(addr).expect("connect");
    let mut pending = VecDeque::new();
    let mut sent = 0usize;
    let mut done = 0usize;
    let t0 = Instant::now();
    while done < total {
        while sent < total && pending.len() < depth {
            let id = p
                .send(Op::PredictBatch {
                    job: JobKind::Sort,
                    machine_type: None,
                    rows: rows.clone(),
                })
                .expect("send");
            pending.push_back(id);
            sent += 1;
        }
        let id = pending.pop_front().expect("pipeline not empty");
        let b = BatchPrediction::from_json(&p.wait(id).expect("wait")).expect("payload");
        assert!(b.cached, "pipelined load loop must stay on the warm path");
        done += 1;
    }
    total as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let backend = common::backend();
    let smoke = common::smoke();
    println!("== E9: hub load — reactor-transport throughput over TCP ==\n");

    // Cold: fresh server per sample; the first predict_batch pays the fit.
    let cold_samples = if smoke { 1 } else { 3 };
    let mut cold = Vec::new();
    for _ in 0..cold_samples {
        let svc = service(backend.clone());
        let server = HubServer::start_with(
            "127.0.0.1:0",
            svc,
            ServerConfig { workers: 8, max_conns: 256, ..ServerConfig::default() },
        )
        .expect("start hub");
        let mut c = HubClient::connect(&server.addr.to_string()).expect("connect");
        let t0 = Instant::now();
        let b = c.predict_batch(JobKind::Sort, None, &rows()).expect("predict");
        assert!(!b.cached, "first request on a fresh server must be a cold fit");
        cold.push(t0.elapsed().as_secs_f64());
        server.shutdown();
    }
    let cold_mean = cold.iter().sum::<f64>() / cold.len() as f64;
    println!(
        "  cold predict_batch (fit incl.)   {:>10.1} ms/req  ({:>7.1} req/s)",
        cold_mean * 1e3,
        1.0 / cold_mean
    );

    // Warm: one server, primed once, then driven at increasing K.
    let warm_total = if smoke { 80 } else { WARM_TOTAL_REQS };
    let svc = service(backend.clone());
    let server = HubServer::start_with(
        "127.0.0.1:0",
        svc,
        ServerConfig { workers: 16, max_conns: 256, ..ServerConfig::default() },
    )
    .expect("start hub");
    let addr = server.addr.to_string();
    let mut prime = HubClient::connect(&addr).expect("connect");
    prime.predict_batch(JobKind::Sort, None, &rows()).expect("prime");
    drop(prime);
    drive(&addr, 1, if smoke { 10 } else { 50 }); // unmeasured warmup of the whole path

    let mut per_k: Vec<(usize, f64)> = Vec::new();
    for &k in &CLIENT_COUNTS {
        let rps = drive(&addr, k, warm_total / k);
        println!("  warm predict_batch, {k:>2} client(s)  {rps:>10.0} req/s");
        per_k.push((k, rps));
    }
    let rps1 = per_k[0].1;
    let rps_max = per_k.last().unwrap().1;
    let scaling = rps_max / rps1.max(1e-12);
    println!("\n  -> warm scaling, {} clients vs 1: {scaling:.2}x", CLIENT_COUNTS[3]);

    // Pipelined vs roundtrip, same warm server, ONE connection: a sliding
    // window of in-flight requests hides the per-request RTT behind
    // server-side processing.
    let pipe_total = if smoke { 200 } else { 2000 };
    let pipe_rps = drive_pipelined(&addr, pipe_total, PIPELINE_DEPTH);
    let speedup = pipe_rps / rps1.max(1e-12);
    println!(
        "  pipelined depth {PIPELINE_DEPTH}, 1 conn     {pipe_rps:>10.0} req/s  \
         ({speedup:.2}x vs roundtrip)"
    );

    // The whole warm + pipelined phase was served by the single primed fit.
    let mut c = HubClient::connect(&addr).expect("connect");
    let stats = c.stats().expect("stats");
    assert_eq!(stats.fits, 1, "warm load loop must never refit");
    server.shutdown();

    // Idle-connection herd: IDLE_CONNS mostly-idle pipelined connections
    // parked on the reactor (one fd each, no worker held) while 8 active
    // clients measure warm single-row predict latency.
    let svc = service(backend.clone());
    let server = HubServer::start_with(
        "127.0.0.1:0",
        svc,
        ServerConfig {
            workers: 4,
            max_conns: 512,
            idle_timeout: Duration::from_secs(3600),
            ..ServerConfig::default()
        },
    )
    .expect("start hub");
    let addr = server.addr.to_string();
    let mut probe = HubClient::connect(&addr).expect("connect");
    probe.predict_batch(JobKind::Sort, None, &rows()).expect("prime");

    let mut idle: Vec<PipelinedClient> = Vec::new();
    for _ in 0..IDLE_CONNS {
        let mut p = PipelinedClient::connect(&addr).expect("idle connect");
        let id = p.send_stats().expect("send");
        p.wait_stats(id).expect("stats");
        idle.push(p);
    }
    let open = probe.stats().expect("stats").open_connections;
    assert!(open >= IDLE_CONNS as u64, "hub reports only {open} open connections");

    let active = 8;
    let idle_per_client = if smoke { 25 } else { 200 };
    let t0 = Instant::now();
    let mut lat_ms: Vec<f64> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..active {
            let addr = addr.clone();
            handles.push(scope.spawn(move || {
                let mut c = HubClient::connect(&addr).expect("connect");
                let mut lat = Vec::with_capacity(idle_per_client);
                for i in 0..idle_per_client {
                    let row = [2.0 + (i % 11) as f64, 10.0 + (i % 20) as f64];
                    let t = Instant::now();
                    let p = c.predict(JobKind::Sort, None, &row).expect("predict");
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                    assert!(p.cached, "active clients must stay on the warm path");
                }
                lat
            }));
        }
        handles.into_iter().flat_map(|h| h.join().expect("active client")).collect()
    });
    let idle_rps = (active * idle_per_client) as f64 / t0.elapsed().as_secs_f64();
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat_ms[lat_ms.len() / 2];
    let p99 = lat_ms[(lat_ms.len() * 99 / 100).min(lat_ms.len() - 1)];
    println!(
        "  {IDLE_CONNS} idle conns + {active} active   p50 {p50:>6.2} ms  p99 {p99:>6.2} ms  \
         ({idle_rps:>7.0} req/s)"
    );

    // Telemetry after the herd: the `metrics` op must come back with
    // internally consistent per-stage histograms — nonzero counts for
    // every reactor stage the herd exercised, and disjoint stage sums
    // bounded by the end-to-end time.
    let m = probe.metrics().expect("metrics");
    let stage = |name: &str| {
        let h = m.histogram(name).unwrap_or_else(|| panic!("missing histogram `{name}`"));
        assert!(h.count > 0, "{name}: zero count after the herd run");
        h
    };
    let parts = stage("stage_decode").sum_us
        + stage("stage_queue_wait").sum_us
        + stage("stage_service").sum_us
        + stage("stage_dispatch").sum_us
        + stage("stage_reply_write").sum_us;
    let total = stage("stage_request_total");
    assert!(
        parts <= total.sum_us,
        "stage sums exceed end-to-end time: {parts} > {}",
        total.sum_us
    );
    let (total_count, total_p50, total_p99) = (total.count, total.p50_us, total.p99_us);
    let stage_frac = parts as f64 / total.sum_us.max(1) as f64;
    println!(
        "  metrics: request_total n={total_count}  p50 {total_p50} us  p99 {total_p99} us  \
         (stages cover {:.0}% of e2e)",
        stage_frac * 100.0
    );
    let prom = m.render_prometheus();
    std::fs::write("BENCH_hub_metrics.prom", &prom).expect("write metrics text");
    println!("[bench] wrote BENCH_hub_metrics.prom ({} bytes)", prom.len());
    drop(idle);
    server.shutdown();

    // Idle-telemetry overhead proxy: the per-record cost of the hot-path
    // histogram instrument (two shard-local relaxed RMWs). This is the
    // only cost the serving path pays when nobody polls `metrics`.
    let hist = c3o::obs::Histogram::new();
    let probe_n = 1_000_000u64;
    let t0 = Instant::now();
    for i in 0..probe_n {
        hist.record(i & 0xFFFF);
    }
    let record_ns = t0.elapsed().as_nanos() as f64 / probe_n as f64;
    println!("  histogram record cost            {record_ns:>8.1} ns/record");

    // Coalescing: concurrent single-row predicts of the same
    // (job, machine_type) folded into batched model calls.
    let svc = service(backend.clone());
    let window = Duration::from_millis(2);
    let server = HubServer::start_with(
        "127.0.0.1:0",
        svc,
        ServerConfig { workers: 16, coalesce_window: window, ..ServerConfig::default() },
    )
    .expect("start hub");
    let addr = server.addr.to_string();
    let mut probe = HubClient::connect(&addr).expect("connect");
    probe.predict_batch(JobKind::Sort, None, &rows()).expect("prime");

    let co_clients = 8;
    let co_per_client = if smoke { 30 } else { 150 };
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..co_clients {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut c = HubClient::connect(&addr).expect("connect");
                for i in 0..co_per_client {
                    let row = [2.0 + ((t + i) % 11) as f64, 15.0];
                    let p = c.predict(JobKind::Sort, None, &row).expect("predict");
                    assert!(p.runtime_s.is_finite() && p.runtime_s > 0.0);
                }
            });
        }
    });
    let co_rps = (co_clients * co_per_client) as f64 / t0.elapsed().as_secs_f64();
    let coalesced = probe.stats().expect("stats").coalesced_predicts;
    println!(
        "  coalescing {window:?}, {co_clients} clients     {co_rps:>10.0} req/s  \
         ({coalesced} predicts coalesced)"
    );
    server.shutdown();

    let warm: Vec<Json> = per_k
        .iter()
        .map(|&(k, rps)| {
            Json::obj(vec![
                ("clients", Json::Num(k as f64)),
                ("rps", Json::Num(rps)),
            ])
        })
        .collect();
    common::write_bench_json(
        "hub_load",
        Json::obj(vec![
            ("job", Json::Str("sort".to_string())),
            ("rows_per_request", Json::Num(ROWS_PER_REQUEST as f64)),
            ("cold_s_per_req", Json::Num(cold_mean)),
            ("cold_rps", Json::Num(1.0 / cold_mean)),
            ("warm", Json::Arr(warm)),
            ("warm_scaling_8_vs_1", Json::Num(scaling)),
            (
                "pipelined",
                Json::obj(vec![
                    ("depth", Json::Num(PIPELINE_DEPTH as f64)),
                    ("rps", Json::Num(pipe_rps)),
                    ("sync_rps", Json::Num(rps1)),
                    ("speedup", Json::Num(speedup)),
                ]),
            ),
            (
                "idle_conns",
                Json::obj(vec![
                    ("idle", Json::Num(IDLE_CONNS as f64)),
                    ("active", Json::Num(active as f64)),
                    ("open_connections", Json::Num(open as f64)),
                    ("p50_ms", Json::Num(p50)),
                    ("p99_ms", Json::Num(p99)),
                    ("rps", Json::Num(idle_rps)),
                ]),
            ),
            (
                "coalesce",
                Json::obj(vec![
                    ("window_us", Json::Num(window.as_micros() as f64)),
                    ("rps", Json::Num(co_rps)),
                    ("coalesced", Json::Num(coalesced as f64)),
                ]),
            ),
            (
                "telemetry",
                Json::obj(vec![
                    ("request_total_count", Json::Num(total_count as f64)),
                    ("request_total_p50_us", Json::Num(total_p50 as f64)),
                    ("request_total_p99_us", Json::Num(total_p99 as f64)),
                    ("stage_coverage_of_e2e", Json::Num(stage_frac)),
                    ("record_ns", Json::Num(record_ns)),
                ]),
            ),
        ]),
    );
}
