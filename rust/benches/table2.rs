//! E2 — Table II: runtime prediction accuracy of all models and the C3O
//! predictor, local-only vs globally shared training data.
//!
//! Reproduces the paper's protocol (300 train-test splits per cell, mean
//! MAPE; C3O_SPLITS env var overrides for quick runs) and checks the
//! paper's qualitative claims:
//!   * Ernest degrades badly local → global (it ignores context),
//!   * GBM *improves* with global data,
//!   * C3O tracks its best constituent within ~0.5 pp,
//!   * C3O's global MAPE stays low on every job (paper: < 3%).

mod common;

use c3o::bench::time_once;
use c3o::cloud::Catalog;
use c3o::data::JobKind;
use c3o::eval::{self, Scenario, Table2Config};
use c3o::sim::{generate_all, GeneratorConfig};

fn main() {
    let backend = common::backend();
    let catalog = Catalog::aws_like();
    let datasets: Vec<_> = generate_all(&GeneratorConfig::default(), &catalog)
        .expect("generate")
        .into_iter()
        .map(|d| d.for_machine(eval::TARGET_MACHINE))
        .collect();

    let cfg = Table2Config { splits: common::splits(), ..Default::default() };
    println!("[bench] table2: {} splits per cell\n", cfg.splits);
    let (result, dt) = time_once(|| eval::run_table2(&datasets, &cfg, &backend).expect("table2"));
    println!("{}", eval::table2::render(&result));
    println!("harness wall-clock: {dt:.1}s\n");

    // CSV for plotting.
    let rows: Vec<String> = result
        .cells
        .iter()
        .map(|c| {
            format!(
                "{},{},{},{:.4},{:.4},{}",
                c.job,
                c.model,
                match c.scenario {
                    Scenario::Local => "local",
                    Scenario::Global => "global",
                },
                c.mape,
                c.mape_std,
                c.splits
            )
        })
        .collect();
    common::write_csv("table2.csv", "job,model,scenario,mape,mape_std,splits", &rows);

    // --- Shape checks against the paper's Table II.
    let get = |job, model, sc| result.get(job, model, sc).map(|c| c.mape);
    let mut failures = Vec::new();
    let mut check = |name: &str, ok: bool| {
        println!("  [{}] {name}", if ok { "ok" } else { "MISMATCH" });
        if !ok {
            failures.push(name.to_string());
        }
    };

    println!("paper-shape checks:");
    for job in [JobKind::Grep, JobKind::Sgd, JobKind::KMeans, JobKind::PageRank] {
        let e_l = get(job, "Ernest", Scenario::Local).unwrap();
        let e_g = get(job, "Ernest", Scenario::Global).unwrap();
        // Paper shows 2-5x degradation; on our substrate PageRank's local
        // pools already contain spill-cliff contexts Ernest cannot fit,
        // so its local baseline is higher and the *ratio* is smaller —
        // the direction is what the claim asserts.
        check(
            &format!("{job}: Ernest degrades on global data ({e_l:.1}% -> {e_g:.1}%)"),
            e_g > e_l * 1.15,
        );
        let g_l = get(job, "GBM", Scenario::Local).unwrap();
        let g_g = get(job, "GBM", Scenario::Global).unwrap();
        check(
            &format!("{job}: GBM improves with global data ({g_l:.1}% -> {g_g:.1}%)"),
            g_g < g_l,
        );
        let c_g = get(job, "C3O", Scenario::Global).unwrap();
        let best_g = ["GBM", "BOM", "OGB"]
            .iter()
            .map(|m| get(job, m, Scenario::Global).unwrap())
            .fold(f64::INFINITY, f64::min);
        check(
            &format!("{job}: C3O within 1 pp of best constituent ({c_g:.2}% vs {best_g:.2}%)"),
            c_g <= best_g + 1.0,
        );
    }
    for job in JobKind::ALL {
        if let Some(c_g) = get(job, "C3O", Scenario::Global) {
            // Paper: < 3% on real EMR data. Our simulated substrate has
            // harder cliffs and smaller per-machine pools; < 15% is the
            // calibrated bound (EXPERIMENTS.md §E2 discusses the gap).
            check(&format!("{job}: C3O global MAPE low ({c_g:.2}%)"), c_g < 15.0);
        }
        // Collaboration helps: global <= local for the C3O predictor.
        if let (Some(l), Some(g)) =
            (get(job, "C3O", Scenario::Local), get(job, "C3O", Scenario::Global))
        {
            check(
                &format!("{job}: C3O global beats local ({g:.2}% vs {l:.2}%)"),
                g <= l + 0.5,
            );
        }
    }
    // Sort: C3O must stay competitive with Ernest on the one job that is
    // parametric-friendly (paper: C3O 2.61% strictly beats Ernest 5.82%).
    let e = get(JobKind::Sort, "Ernest", Scenario::Global).unwrap();
    let c = get(JobKind::Sort, "C3O", Scenario::Global).unwrap();
    check(
        &format!("sort: C3O competitive with Ernest ({c:.2}% vs {e:.2}%)"),
        c < e + 2.0,
    );

    if failures.is_empty() {
        println!("\nall paper-shape checks passed");
    } else {
        println!("\n{} shape check(s) failed:", failures.len());
        for f in &failures {
            println!("  - {f}");
        }
        std::process::exit(1);
    }
}
