//! E1 — Table I: the shared runtime-data census, plus generation timing.
//!
//! Regenerates the paper's dataset overview (jobs, dataset counts, input
//! sizes, parameters, feature counts) from the workload simulator and
//! benches corpus generation itself.

mod common;

use c3o::bench::{bench, TablePrinter};
use c3o::cloud::Catalog;
use c3o::data::JobKind;
use c3o::sim::{generate_all, generate_job, GeneratorConfig};

fn main() {
    let catalog = Catalog::aws_like();
    let cfg = GeneratorConfig::default();
    let datasets = generate_all(&cfg, &catalog).expect("generate");

    println!("\nTable I: Overview of Runtime Data for Model Evaluation\n");
    let p = TablePrinter::new(vec![10, 8, 16, 14, 12]);
    println!(
        "{}",
        p.row(&[
            "job".into(),
            "runs".into(),
            "input sizes".into(),
            "scale-outs".into(),
            "#features".into(),
        ])
    );
    println!("{}", p.sep());
    let mut csv = Vec::new();
    for ds in &datasets {
        let sizes: Vec<f64> = ds.records.iter().map(|r| r.data_size_gb).collect();
        let lo = sizes.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = sizes.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let so = ds.scale_outs();
        let row = [
            ds.job.to_string(),
            ds.len().to_string(),
            if hi < 1.0 {
                format!("{:.0}-{:.0} MB", lo * 1000.0, hi * 1000.0)
            } else {
                format!("{lo:.0}-{hi:.0} GB")
            },
            format!("{}-{}", so.first().unwrap(), so.last().unwrap()),
            format!("3+{}", ds.job.context_features()),
        ];
        println!("{}", p.row(&row.to_vec()));
        csv.push(row.join(","));
    }
    let total: usize = datasets.iter().map(|d| d.len()).sum();
    println!("{}", p.sep());
    println!("total unique experiments: {total} (paper: 930)\n");
    assert_eq!(total, 930);

    // Paper-check: per-job census.
    for ds in &datasets {
        assert_eq!(ds.len(), ds.job.experiment_count(), "{}", ds.job);
    }
    common::write_csv("table1.csv", "job,runs,input_sizes,scale_outs,features", &csv);

    // Generation benches (each experiment = 5 simulated executions).
    println!("generation timing:");
    for job in [JobKind::Sort, JobKind::PageRank] {
        let r = bench(&format!("generate_job({job})"), 1, 5, || {
            generate_job(job, &cfg, &catalog).unwrap()
        });
        println!("  {}", r.per_iter_display());
    }
    let r = bench("generate_all(930 experiments x5 reps)", 1, 3, || {
        generate_all(&cfg, &catalog).unwrap()
    });
    println!("  {}", r.per_iter_display());
}
