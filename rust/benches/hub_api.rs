//! E8 — the hub API service layer: cold fit vs fitted-model cache.
//!
//! Measures `predict_batch` through `PredictionService` (the exact path a
//! v1 `predict_batch` frame takes after parsing) in two regimes:
//!
//!   * cold — a fresh service per call: every batch pays the full dynamic
//!     model selection fit (CV all candidates + refit winner),
//!   * warm — one long-lived service: every batch is answered from the
//!     fitted-model cache, zero refits.
//!
//! The ratio is what the server-side cache buys a loaded hub: the paper's
//! 10-30 s selection phase amortizes across every user of a repository
//! instead of being paid per request.

mod common;

use std::sync::Arc;

use c3o::api::service::PredictionService;
use c3o::bench::bench;
use c3o::cloud::Catalog;
use c3o::data::JobKind;
use c3o::hub::{HubState, Repository, ValidationPolicy};
use c3o::runtime::FitBackend;
use c3o::sim::{generate_job, GeneratorConfig};
use c3o::util::json::Json;

fn shared_state() -> Arc<HubState> {
    let catalog = Catalog::aws_like();
    let state = Arc::new(HubState::new());
    let mut repo = Repository::new(JobKind::Sort, "standard Spark sort");
    repo.maintainer_machine = Some("m5.xlarge".to_string());
    repo.data = generate_job(JobKind::Sort, &GeneratorConfig::default(), &catalog)
        .expect("generate corpus");
    state.insert(repo);
    state
}

fn make_service(state: Arc<HubState>, backend: Arc<dyn FitBackend>) -> PredictionService {
    PredictionService::new(state, Catalog::aws_like(), ValidationPolicy::default(), backend)
}

fn main() {
    let backend = common::backend();
    // Built once: the corpus is shared; only the service (and hence the
    // model cache) differs between the cold and warm regimes.
    let state = shared_state();
    let mut csv = Vec::new();
    let mut summary = Vec::new();

    println!("== E8: hub API — cold fit vs fitted-model cache ==\n");
    for &nrows in &[11usize, 64, 256] {
        let rows: Vec<Vec<f64>> = (0..nrows)
            .map(|i| vec![2.0 + (i % 11) as f64, 10.0 + (i % 20) as f64])
            .collect();

        // Cold: a fresh service per iteration — every call refits.
        let (st, be) = (state.clone(), backend.clone());
        let r_cold = bench(&format!("predict_batch_cold/{nrows}"), 1, 5, || {
            let svc = make_service(st.clone(), be.clone());
            svc.predict_batch(JobKind::Sort, None, &rows).unwrap()
        });
        println!("  {}", r_cold.per_iter_display());

        // Warm: one service, primed once — served from the cache.
        let svc = make_service(state.clone(), backend.clone());
        svc.predict_batch(JobKind::Sort, None, &rows).unwrap();
        let r_warm = bench(&format!("predict_batch_warm/{nrows}"), 3, 30, || {
            svc.predict_batch(JobKind::Sort, None, &rows).unwrap()
        });
        println!("  {}", r_warm.per_iter_display());

        let (fits, hits, entries) = svc.fit_stats();
        assert_eq!(fits, 1, "warm path must never refit (got {fits} fits)");
        assert_eq!(entries, 1);
        println!(
            "    -> cache speedup: {:.1}x ({} fit, {} hits)\n",
            r_cold.mean_s / r_warm.mean_s.max(1e-12),
            fits,
            hits
        );
        csv.push(format!("predict_batch_cold,{nrows},{:.6}", r_cold.mean_s));
        csv.push(format!("predict_batch_warm,{nrows},{:.6}", r_warm.mean_s));
        summary.push(Json::obj(vec![
            ("rows", Json::Num(nrows as f64)),
            ("cold_mean_s", Json::Num(r_cold.mean_s)),
            ("warm_mean_s", Json::Num(r_warm.mean_s)),
            ("cache_speedup", Json::Num(r_cold.mean_s / r_warm.mean_s.max(1e-12))),
        ]));
    }

    common::write_csv("hub_api.csv", "bench,rows,mean_s", &csv);
    common::write_bench_json("hub_api", Json::Arr(summary));
}
