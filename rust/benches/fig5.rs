//! E3 — Fig. 5: prediction accuracy vs training-data availability.
//!
//! Train sizes 3, 6, …, 30 drawn from the global pool, 300 splits per
//! point (C3O_SPLITS overrides). Checks the paper's qualitative findings:
//!   * BOM is particularly poor below ~10 points (its SSM starves),
//!   * models that win at 3 points are not the winners at 30,
//!   * the C3O selector converges toward its best constituent.

mod common;

use c3o::bench::time_once;
use c3o::cloud::Catalog;
use c3o::eval::{self, Fig5Config};
use c3o::sim::{generate_all, GeneratorConfig};

fn main() {
    let backend = common::backend();
    let catalog = Catalog::aws_like();
    let datasets: Vec<_> = generate_all(&GeneratorConfig::default(), &catalog)
        .expect("generate")
        .into_iter()
        .map(|d| d.for_machine(eval::TARGET_MACHINE))
        .collect();

    let cfg = Fig5Config { splits: common::splits(), ..Default::default() };
    println!("[bench] fig5: {} splits per point\n", cfg.splits);

    let mut csv = Vec::new();
    let mut results = Vec::new();
    let (_, dt) = time_once(|| {
        for ds in &datasets {
            let r = eval::run_fig5(ds, &cfg, &backend).expect("fig5");
            println!("{}", eval::fig5::render(&r));
            for p in &r.points {
                csv.push(format!("{},{},{},{:.4}", r.job, p.model, p.train_size, p.mape));
            }
            results.push(r);
        }
    });
    println!("harness wall-clock: {dt:.1}s\n");
    common::write_csv("fig5.csv", "job,model,train_size,mape", &csv);

    // --- Paper-shape checks.
    let mut failures = Vec::new();
    let mut check = |name: &str, ok: bool| {
        println!("  [{}] {name}", if ok { "ok" } else { "MISMATCH" });
        if !ok {
            failures.push(name.to_string());
        }
    };
    println!("paper-shape checks:");
    for r in &results {
        let at = |model: &str, n: usize| {
            r.series(model)
                .into_iter()
                .find(|&(s, _)| s == n)
                .map(|(_, m)| m)
                .unwrap()
        };
        // Every model improves substantially from 3 to 30 points.
        for model in ["GBM", "C3O"] {
            let (a, b) = (at(model, 3), at(model, 30));
            check(&format!("{}: {model} improves 3->30 ({a:.1}% -> {b:.1}%)", r.job), b < a);
        }
        // BOM is particularly poor below 10 points relative to its own
        // 30-point accuracy (the paper's §VI-C-b observation).
        let (bom3, bom30) = (at("BOM", 3), at("BOM", 30));
        check(
            &format!("{}: BOM bad when starved ({bom3:.1}% vs {bom30:.1}% at 30)", r.job),
            bom3 > 1.5 * bom30,
        );
        // C3O at 30 points tracks the best constituent within 2 pp.
        let best30 = ["GBM", "BOM", "OGB"]
            .iter()
            .map(|m| at(m, 30))
            .fold(f64::INFINITY, f64::min);
        let c30 = at("C3O", 30);
        check(
            &format!("{}: C3O tracks best at 30 ({c30:.1}% vs {best30:.1}%)", r.job),
            c30 <= best30 + 2.0,
        );
    }

    if failures.is_empty() {
        println!("\nall paper-shape checks passed");
    } else {
        println!("\n{} shape check(s) failed:", failures.len());
        for f in &failures {
            println!("  - {f}");
        }
        std::process::exit(1);
    }
}
