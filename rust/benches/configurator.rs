//! E5 — the §IV-B scale-out selection rule, measured empirically.
//!
//! For a sweep of confidence levels the configurator picks scale-outs for
//! fresh jobs; each choice is then executed many times on the simulator
//! and the observed deadline-hit rate is compared with the requested
//! confidence (the operational guarantee of the erf formula). Also benches
//! configure() latency — the interactive path a user waits on.

mod common;

use std::sync::Arc;

use c3o::bench::bench;
use c3o::cloud::Catalog;
use c3o::configurator::{configure, UserGoals};
use c3o::data::JobKind;
use c3o::eval::TARGET_MACHINE;
use c3o::sim::{generate_job, GeneratorConfig, JobInput, WorkloadModel};
use c3o::util::prng::Pcg;

fn main() {
    let backend = common::backend();
    let catalog = Catalog::aws_like();
    let shared = generate_job(JobKind::Grep, &GeneratorConfig::default(), &catalog)
        .expect("gen");
    let model = WorkloadModel::default();
    let mt = catalog.get(TARGET_MACHINE).expect("mt");

    println!("== E5: erf-confidence scale-out selection ==\n");
    println!(
        "{:<12} {:>10} {:>14} {:>14} {:>12}",
        "confidence", "jobs", "mean scale", "hit rate", "target"
    );

    let mut csv = Vec::new();
    let mut rng = Pcg::seed(0xE5);
    let mut failures = Vec::new();
    for &c in &[0.5, 0.7, 0.8, 0.9, 0.95] {
        let mut hits = 0usize;
        let mut total = 0usize;
        let mut scale_sum = 0u64;
        for _ in 0..25 {
            let d = rng.range_f64(10.0, 20.0);
            let ratio = *rng.choose(&[0.001, 0.01, 0.1]);
            let input = JobInput::new(JobKind::Grep, d, vec![ratio]);
            let t_fast = model.mean_runtime(mt, 12, &input);
            let t_slow = model.mean_runtime(mt, 2, &input);
            let deadline = t_fast + rng.range_f64(0.35, 0.9) * (t_slow - t_fast);
            let goals = UserGoals { deadline_s: Some(deadline), confidence: c };
            let choice = match configure(
                &catalog,
                &shared,
                Some(TARGET_MACHINE),
                &input,
                &goals,
                backend.clone(),
            ) {
                Ok(ch) => ch,
                Err(_) => continue,
            };
            scale_sum += choice.scale_out as u64;
            // 40 executions of the chosen configuration.
            for _ in 0..40 {
                let t = model.sample_runtime(mt, choice.scale_out, &input, &mut rng);
                total += 1;
                if t <= deadline {
                    hits += 1;
                }
            }
        }
        let rate = hits as f64 / total.max(1) as f64;
        let njobs = total / 40;
        println!(
            "{c:<12} {njobs:>10} {:>14.2} {:>13.1}% {:>11.0}%",
            scale_sum as f64 / njobs.max(1) as f64,
            rate * 100.0,
            c * 100.0
        );
        csv.push(format!("{c},{njobs},{rate:.4}"));
        // The §IV-B guarantee, with finite-sample slack.
        if rate < c - 0.08 {
            failures.push(format!("confidence {c}: hit rate {rate:.2} too low"));
        }
    }
    common::write_csv("configurator.csv", "confidence,jobs,hit_rate", &csv);

    // --- configure() latency (interactive path).
    println!(
        "\nconfigure() latency (fit + sweep, Grep n={}):",
        shared.for_machine(TARGET_MACHINE).len()
    );
    let input = JobInput::new(JobKind::Grep, 15.0, vec![0.01]);
    let goals = UserGoals { deadline_s: Some(600.0), confidence: 0.95 };
    let r = bench("configure/grep", 1, 10, || {
        configure(&catalog, &shared, Some(TARGET_MACHINE), &input, &goals, backend.clone())
            .unwrap()
    });
    println!("  {}", r.per_iter_display());

    if failures.is_empty() {
        println!("\nall confidence checks passed");
    } else {
        for f in &failures {
            println!("  MISMATCH: {f}");
        }
        std::process::exit(1);
    }
}
